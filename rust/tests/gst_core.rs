//! GstTask conformance suite: guarantees every task gets from the shared
//! `GstCore` driver, exercised over the real AOT artifacts (skipped when
//! `artifacts/` is not built, like the rest of the integration tier).
//!
//! The pure-logic half of the suite (SED weights per `SedMode`, table
//! write-back versioning, batch-padding rule) lives in unit tests inside
//! `src/train/core.rs`; this file covers what needs a real engine — above
//! all the worker-count invariance contract: `cfg.workers` is an
//! execution knob, so workers=1 and workers=4 must produce **identical
//! parameters** after training.

use gst::datasets::{MalnetDataset, MalnetSplit, TpuDataset};
use gst::runtime::Engine;
use gst::train::{MalnetTrainer, Method, TpuTrainer, TrainConfig};

fn dir(v: &str) -> Option<String> {
    let d = format!("{}/artifacts/{v}", env!("CARGO_MANIFEST_DIR"));
    std::path::Path::new(&d).is_dir().then_some(d)
}

fn cfg(method: Method, workers: usize) -> TrainConfig {
    TrainConfig {
        method,
        epochs: 1,
        finetune_epochs: 0,
        eval_every: 1,
        seed: 5,
        workers,
        micro_batches: 4,
        ..TrainConfig::default()
    }
}

#[test]
fn workers_1_and_4_produce_identical_parameters_malnet() {
    let Some(d) = dir("malnet_sage_n128") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let eng = Engine::open(&d).unwrap();
    let data = MalnetDataset::generate(MalnetSplit::Tiny, 40, 3);
    let run = |workers: usize| {
        let mut tr =
            MalnetTrainer::new(&eng, &data, cfg(Method::GstED, workers))
                .unwrap();
        let res = tr.train().unwrap();
        (tr.ps.values.clone(), tr.ps.m.clone(), res.test_metric)
    };
    let (p1, m1, acc1) = run(1);
    let (p4, m4, acc4) = run(4);
    // identical parameters AND Adam moments => the whole gradient
    // sequence (sampling, SED, staleness, averaging) matched bit-for-bit
    assert_eq!(p1, p4, "parameters diverge with worker count");
    assert_eq!(m1, m4, "Adam moments diverge with worker count");
    assert_eq!(acc1, acc4);
}

#[test]
fn fill_cache_budget_never_changes_parameters() {
    let Some(d) = dir("malnet_sage_n128") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let eng = Engine::open(&d).unwrap();
    let data = MalnetDataset::generate(MalnetSplit::Tiny, 40, 3);
    // fill_cache_mb is execution-only, exactly like workers: a cached
    // fill block is bit-identical to a fresh fill, so any budget (and
    // any worker count on top) trains the same parameters
    let run = |fill_cache_mb: usize, workers: usize| {
        let mut c = cfg(Method::GstED, workers);
        c.fill_cache_mb = fill_cache_mb;
        let mut tr = MalnetTrainer::new(&eng, &data, c).unwrap();
        let res = tr.train().unwrap();
        (tr.ps.values.clone(), tr.ps.m.clone(), res)
    };
    let (p0, m0, r0) = run(0, 1);
    let (p1, m1, r1) = run(64, 1);
    let (p4, m4, r4) = run(64, 4);
    assert_eq!(p0, p1, "parameters diverge with fill cache budget");
    assert_eq!(m0, m1, "Adam moments diverge with fill cache budget");
    assert_eq!(p0, p4, "parameters diverge with cache + workers");
    assert_eq!(m0, m4, "Adam moments diverge with cache + workers");
    assert_eq!(r0.test_metric, r1.test_metric);
    assert_eq!(r0.test_metric, r4.test_metric);
    // the disabled run reports no cache traffic; the budgeted runs hit
    assert_eq!(r0.fill_cache.total(), 0);
    assert!(r1.fill_cache.hits > 0, "expected fill-cache hits");
    // every run serves parameter literals from the engine cache
    assert!(r1.param_cache.hits > 0, "expected param-literal hits");
}

#[test]
fn workers_1_and_4_produce_identical_parameters_tpu() {
    let Some(d) = dir("tpu_sage_n128") else {
        eprintln!("skipping: tpu artifacts not built");
        return;
    };
    let eng = Engine::open(&d).unwrap();
    let data = TpuDataset::generate(6, 6, 11);
    let run = |workers: usize| {
        let mut tr =
            TpuTrainer::new(&eng, &data, cfg(Method::GstEFD, workers))
                .unwrap();
        let res = tr.train().unwrap();
        (tr.ps.values.clone(), res.test_metric)
    };
    let (p1, acc1) = run(1);
    let (p4, acc4) = run(4);
    assert_eq!(p1, p4, "parameters diverge with worker count");
    assert_eq!(acc1, acc4);
}

#[test]
fn table_writeback_versions_advance_during_training() {
    let Some(d) = dir("malnet_sage_n128") else {
        return;
    };
    let eng = Engine::open(&d).unwrap();
    let data = MalnetDataset::generate(MalnetSplit::Tiny, 40, 3);
    let mut tr =
        MalnetTrainer::new(&eng, &data, cfg(Method::GstE, 2)).unwrap();
    assert_eq!(tr.table.coverage(), 0.0);
    tr.train().unwrap();
    let now = tr.steps_done();
    assert!(now > 0);
    assert!(tr.table.coverage() > 0.0);
    // every written entry's version is a real step index (< now), and at
    // least one write happened after the very first optimization step
    let mut min_age = u32::MAX;
    for g in 0..tr.table.num_graphs() {
        for s in 0..tr.table.segments_of(g) {
            if let Some(age) = tr.table.staleness(g, s, now) {
                assert!(age <= now, "version out of range");
                min_age = min_age.min(age);
            }
        }
    }
    assert!(
        min_age < now,
        "no table entry was written by a later training step"
    );
}

#[test]
fn micro_batches_scale_the_effective_batch() {
    let Some(d) = dir("malnet_sage_n128") else {
        return;
    };
    let eng = Engine::open(&d).unwrap();
    let data = MalnetDataset::generate(MalnetSplit::Tiny, 40, 3);
    // 4 micro-batches per step over the same epoch = 1/4 the optimizer
    // applies of the 1-micro-batch run (drop-last grouping)
    let steps = |micro: usize| {
        let mut c = cfg(Method::GstED, 1);
        c.micro_batches = micro;
        let mut tr = MalnetTrainer::new(&eng, &data, c).unwrap();
        tr.train().unwrap();
        // steps_done counts micro-batches; the timer counts optimizer
        // steps (groups)
        (tr.steps_done(), tr.timer.count())
    };
    let (micro1, groups1) = steps(1);
    let (micro4, groups4) = steps(4);
    assert_eq!(micro1, micro4, "same micro-batch stream either way");
    assert_eq!(groups4, (groups1 + 3) / 4);
}
