//! Segment extraction and padding to the AOT fixed shapes.
//!
//! The L2 model consumes `(nodes [B,N,F], adj [B,N,N], mask [B,N])` with a
//! **dense normalized adjacency** per segment — the TPU hardware adaptation
//! (DESIGN.md §Hardware-Adaptation): GST's bounded segment size makes the
//! dense N×N block small enough for VMEM, turning message passing into MXU
//! matmuls instead of gather/scatter.
//!
//! [`SegmentedGraph`] holds one parent graph's segments (node lists, or
//! explicit edge sets for vertex-cut) and fills caller-provided padded
//! buffers on demand — no per-fetch allocation on the training hot path.

use crate::graph::Csr;
use crate::partition::SegmentSet;

/// Which normalized adjacency the backbone expects (from the manifest).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdjNorm {
    /// GCN: D^-1/2 (A + I) D^-1/2
    SymSelfLoop,
    /// SAGE/GPS: D^-1 A (row mean, no self loops)
    RowMean,
}

impl AdjNorm {
    pub fn parse(s: &str) -> Option<AdjNorm> {
        match s {
            "sym_selfloop" => Some(AdjNorm::SymSelfLoop),
            "row_mean" => Some(AdjNorm::RowMean),
            _ => None,
        }
    }
}

/// One parent graph cut into segments.
pub struct SegmentedGraph {
    /// Sorted node ids per segment.
    pub segments: Vec<Vec<u32>>,
    /// Intra-segment edges in *local* (segment-relative) indices.
    pub local_edges: Vec<Vec<(u16, u16)>>,
}

impl SegmentedGraph {
    /// Build from a partitioner output. Edge-cut sets use the induced
    /// subgraph; vertex-cut sets use their explicit edge lists.
    pub fn new(g: &Csr, set: &SegmentSet) -> SegmentedGraph {
        let mut local_edges = Vec::with_capacity(set.segments.len());
        for (si, seg) in set.segments.iter().enumerate() {
            let mut rank = std::collections::HashMap::new();
            for (i, &v) in seg.iter().enumerate() {
                rank.insert(v, i as u16);
            }
            let mut edges = Vec::new();
            match &set.edges {
                Some(per_seg) => {
                    for &(u, v) in &per_seg[si] {
                        edges.push((rank[&u], rank[&v]));
                    }
                }
                None => {
                    for (i, &v) in seg.iter().enumerate() {
                        for &w in g.neighbors(v as usize) {
                            if let Some(&j) = rank.get(&w) {
                                if (i as u16) < j {
                                    edges.push((i as u16, j));
                                }
                            }
                        }
                    }
                }
            }
            local_edges.push(edges);
        }
        SegmentedGraph { segments: set.segments.clone(), local_edges }
    }

    pub fn num_segments(&self) -> usize {
        self.segments.len()
    }

    /// Fill one padded slot of a batch. `feats_override` substitutes the
    /// parent graph's features (used by TpuGraphs to bake config one-hots).
    ///
    /// * `nodes_out`: N*F slice, zero-padded
    /// * `adj_out`: N*N slice, normalized per `norm`, zero outside the
    ///   real block
    /// * `mask_out`: N slice of {0,1}
    #[allow(clippy::too_many_arguments)]
    pub fn fill_padded(
        &self,
        g: &Csr,
        seg_idx: usize,
        norm: AdjNorm,
        max_nodes: usize,
        feat_dim: usize,
        feats_override: Option<&[f32]>,
        nodes_out: &mut [f32],
        adj_out: &mut [f32],
        mask_out: &mut [f32],
    ) {
        let seg = &self.segments[seg_idx];
        let n = seg.len();
        assert!(n <= max_nodes, "segment {n} > padded {max_nodes}");
        assert_eq!(nodes_out.len(), max_nodes * feat_dim);
        assert_eq!(adj_out.len(), max_nodes * max_nodes);
        assert_eq!(mask_out.len(), max_nodes);
        nodes_out.fill(0.0);
        adj_out.fill(0.0);
        mask_out.fill(0.0);
        let feats = feats_override.unwrap_or(&g.feats);
        let fdim = g.feat_dim.min(feat_dim);
        for (i, &v) in seg.iter().enumerate() {
            let src = &feats[v as usize * g.feat_dim..][..fdim];
            nodes_out[i * feat_dim..i * feat_dim + fdim].copy_from_slice(src);
            mask_out[i] = 1.0;
        }
        // degree within the segment
        let mut deg = vec![0f32; n];
        for &(u, v) in &self.local_edges[seg_idx] {
            deg[u as usize] += 1.0;
            deg[v as usize] += 1.0;
        }
        match norm {
            AdjNorm::SymSelfLoop => {
                // Â = D^-1/2 (A+I) D^-1/2 with D including the self loop
                let inv_sqrt: Vec<f32> =
                    deg.iter().map(|&d| 1.0 / (d + 1.0).sqrt()).collect();
                for i in 0..n {
                    adj_out[i * max_nodes + i] = inv_sqrt[i] * inv_sqrt[i];
                }
                for &(u, v) in &self.local_edges[seg_idx] {
                    let (u, v) = (u as usize, v as usize);
                    let w = inv_sqrt[u] * inv_sqrt[v];
                    adj_out[u * max_nodes + v] = w;
                    adj_out[v * max_nodes + u] = w;
                }
            }
            AdjNorm::RowMean => {
                for &(u, v) in &self.local_edges[seg_idx] {
                    let (u, v) = (u as usize, v as usize);
                    adj_out[u * max_nodes + v] = 1.0 / deg[u].max(1.0);
                    adj_out[v * max_nodes + u] = 1.0 / deg[v].max(1.0);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::partition::SegmentSet;

    fn path4() -> Csr {
        let mut b = GraphBuilder::new(4, 2);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(2, 3);
        for v in 0..4 {
            b.set_feat(v, &[v as f32, 1.0]);
        }
        b.build()
    }

    fn two_segments() -> SegmentSet {
        SegmentSet { segments: vec![vec![0, 1], vec![2, 3]], edges: None }
    }

    #[test]
    fn local_edges_from_induced() {
        let g = path4();
        let sg = SegmentedGraph::new(&g, &two_segments());
        assert_eq!(sg.local_edges[0], vec![(0, 1)]);
        assert_eq!(sg.local_edges[1], vec![(0, 1)]);
        // the cut edge 1-2 is dropped (the paper's ⊕ approximation)
    }

    #[test]
    fn vertex_cut_edges_respected() {
        let g = path4();
        let set = SegmentSet {
            segments: vec![vec![0, 1, 2], vec![2, 3]],
            edges: Some(vec![vec![(0, 1), (1, 2)], vec![(2, 3)]]),
        };
        let sg = SegmentedGraph::new(&g, &set);
        assert_eq!(sg.local_edges[0], vec![(0, 1), (1, 2)]);
        assert_eq!(sg.local_edges[1], vec![(0, 1)]);
    }

    #[test]
    fn padding_layout_and_mask() {
        let g = path4();
        let sg = SegmentedGraph::new(&g, &two_segments());
        let (n, f) = (3usize, 2usize);
        let mut nodes = vec![9.0; n * f];
        let mut adj = vec![9.0; n * n];
        let mut mask = vec![9.0; n];
        sg.fill_padded(&g, 1, AdjNorm::RowMean, n, f, None, &mut nodes,
                       &mut adj, &mut mask);
        assert_eq!(mask, vec![1.0, 1.0, 0.0]);
        assert_eq!(&nodes[..4], &[2.0, 1.0, 3.0, 1.0]);
        assert_eq!(&nodes[4..], &[0.0, 0.0]); // padded row zeroed
        // row-mean: both real nodes have in-segment degree 1
        assert_eq!(adj[0 * n + 1], 1.0);
        assert_eq!(adj[1 * n + 0], 1.0);
        assert_eq!(adj[2 * n + 2], 0.0); // no self loop on padding
    }

    #[test]
    fn sym_selfloop_rows_normalized() {
        let g = path4();
        let sg = SegmentedGraph::new(&g, &two_segments());
        let n = 4usize;
        let mut nodes = vec![0.0; n * 2];
        let mut adj = vec![0.0; n * n];
        let mut mask = vec![0.0; n];
        sg.fill_padded(&g, 0, AdjNorm::SymSelfLoop, n, 2, None, &mut nodes,
                       &mut adj, &mut mask);
        // deg+1 = 2 for both nodes: diagonal 1/2, off-diagonal 1/2
        assert!((adj[0] - 0.5).abs() < 1e-6);
        assert!((adj[1] - 0.5).abs() < 1e-6);
        assert!((adj[n + 1] - 0.5).abs() < 1e-6);
        // padded diagonal stays zero
        assert_eq!(adj[2 * n + 2], 0.0);
    }

    #[test]
    fn feats_override_used() {
        let g = path4();
        let sg = SegmentedGraph::new(&g, &two_segments());
        let alt = vec![7.0f32; 8];
        let (n, f) = (2usize, 2usize);
        let mut nodes = vec![0.0; n * f];
        let mut adj = vec![0.0; n * n];
        let mut mask = vec![0.0; n];
        sg.fill_padded(&g, 0, AdjNorm::RowMean, n, f, Some(&alt), &mut nodes,
                       &mut adj, &mut mask);
        assert_eq!(nodes, vec![7.0; 4]);
    }

    #[test]
    #[should_panic(expected = "segment")]
    fn oversize_segment_panics() {
        let g = path4();
        let sg = SegmentedGraph::new(&g, &two_segments());
        let mut nodes = vec![0.0; 2];
        let mut adj = vec![0.0; 1];
        let mut mask = vec![0.0; 1];
        sg.fill_padded(&g, 0, AdjNorm::RowMean, 1, 2, None, &mut nodes,
                       &mut adj, &mut mask);
    }
}
