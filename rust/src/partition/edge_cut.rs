//! Simple edge-cut partitioners: Random (the paper's weak baseline — it
//! destroys subgraph structure, Table 6) and BFS (locality-preserving
//! greedy growth; also the fallback splitter for oversize segments).

use super::SegmentSet;
use crate::graph::Csr;
use crate::util::rng::Pcg64;
use std::collections::VecDeque;

/// Random node assignment into ⌈n / max_size⌉ balanced parts.
pub fn random(g: &Csr, max_size: usize, rng: &mut Pcg64) -> SegmentSet {
    let n = g.num_nodes();
    let k = n.div_ceil(max_size);
    let mut nodes: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut nodes);
    let segments = nodes
        .chunks(max_size.min(n).max(1))
        .map(|c| {
            let mut s = c.to_vec();
            s.sort_unstable();
            s
        })
        .collect::<Vec<_>>();
    debug_assert!(segments.len() >= k.min(1));
    SegmentSet { segments, edges: None }
}

/// BFS growth: repeatedly grow a segment from an unvisited seed until it
/// reaches `max_size` nodes, then start a new one. Preserves locality and
/// always terminates with every node in exactly one segment.
pub fn bfs(g: &Csr, max_size: usize) -> SegmentSet {
    let n = g.num_nodes();
    let mut assigned = vec![false; n];
    let mut segments = Vec::new();
    let mut queue = VecDeque::new();
    let mut seg: Vec<u32> = Vec::with_capacity(max_size);
    let mut next_seed = 0usize;
    loop {
        // refill from the next unassigned seed; crucially this continues
        // growing the *current* segment, so hub-heavy graphs (where a BFS
        // frontier dies against already-assigned hubs) cannot fragment
        // into sliver segments — bfs always yields ceil(n / max_size)
        // segments, which is what makes it the memory-packing fallback.
        if queue.is_empty() {
            while next_seed < n && assigned[next_seed] {
                next_seed += 1;
            }
            if next_seed == n {
                break;
            }
            assigned[next_seed] = true;
            queue.push_back(next_seed as u32);
        }
        while let Some(u) = queue.pop_front() {
            seg.push(u);
            if seg.len() == max_size {
                break;
            }
            for &v in g.neighbors(u as usize) {
                if !assigned[v as usize] {
                    assigned[v as usize] = true;
                    queue.push_back(v);
                }
            }
        }
        if seg.len() == max_size {
            // nodes still queued belong to a later segment — unmark them
            for v in queue.drain(..) {
                assigned[v as usize] = false;
            }
            seg.sort_unstable();
            segments.push(std::mem::take(&mut seg));
            seg.reserve(max_size);
        }
        // otherwise the queue drained naturally: keep filling this segment
        // from the next seed on the following iteration
    }
    if !seg.is_empty() {
        seg.sort_unstable();
        segments.push(seg);
    }
    SegmentSet { segments, edges: None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::testing::prop::{forall, Gen};

    fn grid(w: usize, h: usize) -> Csr {
        let mut b = GraphBuilder::new(w * h, 0);
        for y in 0..h {
            for x in 0..w {
                let v = y * w + x;
                if x + 1 < w {
                    b.add_edge(v, v + 1);
                }
                if y + 1 < h {
                    b.add_edge(v, v + w);
                }
            }
        }
        b.build()
    }

    #[test]
    fn bfs_covers_exactly_once() {
        let g = grid(10, 10);
        let set = bfs(&g, 23);
        set.validate(&g, 23).unwrap();
    }

    #[test]
    fn bfs_segments_full_except_last_per_component() {
        let g = grid(8, 8); // connected, 64 nodes
        let set = bfs(&g, 30);
        assert_eq!(set.segments.len(), 3); // 30 + 30 + 4
        let mut sizes: Vec<usize> =
            set.segments.iter().map(|s| s.len()).collect();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![4, 30, 30]);
    }

    #[test]
    fn random_is_balanced() {
        let g = grid(10, 10);
        let mut rng = Pcg64::new(0, 0);
        let set = random(&g, 32, &mut rng);
        set.validate(&g, 32).unwrap();
        assert_eq!(set.segments.len(), 4);
    }

    #[test]
    fn prop_bfs_locality_beats_random() {
        forall("bfs cut <= random cut", 8, Gen::usize(10..30), |&side| {
            let g = grid(side, side);
            let mut rng = Pcg64::new(side as u64, 0);
            let b = bfs(&g, 50).cut_cost(&g);
            let r = random(&g, 50, &mut rng).cut_cost(&g);
            b <= r
        });
    }

    #[test]
    fn handles_single_node() {
        let g = GraphBuilder::new(1, 0).build();
        let set = bfs(&g, 10);
        assert_eq!(set.segments, vec![vec![0]]);
        let mut rng = Pcg64::new(1, 1);
        let set = random(&g, 10, &mut rng);
        assert_eq!(set.segments, vec![vec![0]]);
    }
}
