"""L1 Pallas kernel: fused masked linear attention (Performer-style).

Used by the GPS-lite backbone as the global-mixing half of each layer (the
paper's GraphGPS pairs a local MPNN with Performer attention; full softmax
attention is exactly what makes Graph Transformers OOM on large graphs, and
linear attention is the paper-sanctioned fix).

With feature map phi(x) = relu(x) + eps, attention factorizes as

    out = phi(Q) @ (phi(K)^T V) / (phi(Q) @ sum_j phi(K)_j)

so cost is O(N * H^2) instead of O(N^2 * H), and — crucially for the fused
TPU kernel — the whole segment state (N x H with N<=256, H=64) fits in one
VMEM block. We therefore fuse the entire computation into a single grid step
per segment: two MXU matmuls (H x H outer state, then the N x H read-out)
with the mask applied in the VPU, no HBM round-trips for intermediates.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-6


def _linattn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref):
    q = jnp.maximum(q_ref[0, ...], 0.0) + _EPS  # phi(Q)      (N, H)
    k = jnp.maximum(k_ref[0, ...], 0.0) + _EPS  # phi(K)      (N, H)
    m = mask_ref[0, ...][:, None]  # (N, 1)
    k = k * m
    v = v_ref[0, ...] * m
    kv = jnp.dot(k.T, v, preferred_element_type=jnp.float32)  # (H, H)
    ksum = jnp.sum(k, axis=0)  # (H,)
    num = jnp.dot(q, kv, preferred_element_type=jnp.float32)  # (N, H)
    den = q @ ksum + _EPS  # (N,)
    o_ref[0, ...] = (num / den[:, None]).astype(o_ref.dtype)


def _linattn_pallas(q, k, v, mask):
    bsz, n, h = q.shape
    assert k.shape == v.shape == (bsz, n, h)
    assert mask.shape == (bsz, n)
    return pl.pallas_call(
        _linattn_kernel,
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, n, h), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, n, h), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, n, h), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, n), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, h), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, n, h), jnp.float32),
        interpret=True,
    )(q, k, v, mask)


@jax.custom_vjp
def linear_attention(q, k, v, mask):
    """q, k, v: (B, N, H) f32; mask: (B, N) f32 in {0,1}. Returns (B, N, H).

    Padded nodes contribute nothing as keys/values; their query outputs are
    garbage-free (normalized) but must be masked by the caller before any
    pooling (the model multiplies by mask afterwards anyway).
    """
    return _linattn_pallas(q, k, v, mask)


def _linattn_fwd(q, k, v, mask):
    out = _linattn_pallas(q, k, v, mask)
    return out, (q, k, v, mask, out)


def _linattn_bwd(res, g):
    """Hand-derived VJP of the factorized attention.

    With Q = phi(q), K = phi(k) * m, V = v * m, S = K^T V, u = K^T 1:
        out = (Q S) / (Q u)
    The backward is O(N H^2) like the forward. It is expressed in jnp
    (einsum lowers to the same dot_general XLA fuses around the pallas
    forward); the O(N H^2) contractions dominate and run on the MXU either
    way — see DESIGN.md section Perf for the measured split.
    """
    q, k, v, mask, out = res
    m = mask[..., None]
    qp = jnp.maximum(q, 0.0) + _EPS
    kp = (jnp.maximum(k, 0.0) + _EPS) * m
    vp = v * m
    s = jnp.einsum("bnh,bnd->bhd", kp, vp)  # (B,H,H)
    u = jnp.sum(kp, axis=1)  # (B,H)
    den = jnp.einsum("bnh,bh->bn", qp, u) + _EPS  # (B,N)

    dnum = g / den[..., None]  # (B,N,H)
    dden = -jnp.sum(g * out, axis=-1) / den  # (B,N)
    dqp = (jnp.einsum("bnd,bhd->bnh", dnum, s)
           + dden[..., None] * u[:, None, :])
    ds = jnp.einsum("bnh,bnd->bhd", qp, dnum)  # (B,H,H)
    du = jnp.einsum("bn,bnh->bh", dden, qp)  # (B,H)
    dkp = (jnp.einsum("bnd,bhd->bnh", vp, ds) + du[:, None, :])
    dvp = jnp.einsum("bnh,bhd->bnd", kp, ds)

    dq = dqp * (q > 0.0)
    dk = dkp * m * (k > 0.0)
    dv = dvp * m
    dmask = jnp.zeros_like(mask)  # mask is data, never trained
    return dq, dk, dv, dmask


linear_attention.defvjp(_linattn_fwd, _linattn_bwd)


def vmem_bytes(n: int, h: int) -> int:
    """One grid step keeps q,k,v,out (N,H) + mask + (H,H) state resident."""
    return 4 * (4 * n * h + n + h * h + h)
