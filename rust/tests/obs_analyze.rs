//! Golden tests for the trace/report analytics layer (`obs::analyze`)
//! over the committed fixture `tests/fixtures/trace_small.jsonl` — a
//! hand-written 3-step, 2-worker trace whose every aggregate is known in
//! closed form — plus gate tests over the committed CI baseline.

use gst::obs::analyze::{
    analyze_trace, diff_reports, diff_traces, render_trace_diff,
    trend_analyze,
};
use gst::util::json::Json;

fn fixture() -> String {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/trace_small.jsonl"
    );
    std::fs::read_to_string(path).expect("fixture trace")
}

fn regressed_fixture() -> String {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/trace_small_regressed.jsonl"
    );
    std::fs::read_to_string(path).expect("regressed fixture trace")
}

fn baseline() -> Json {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/baselines/report_baseline.json"
    );
    let text = std::fs::read_to_string(path).expect("committed baseline");
    Json::parse(&text).expect("baseline parses")
}

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() < 1e-9
}

#[test]
fn trace_analysis_matches_the_golden_fixture() {
    let a = analyze_trace(&fixture(), 2).unwrap();
    assert_eq!(a.at("schema").as_str(), Some("gst-trace-analysis/v1"));
    // 28 spans (3 steps × 9 + one eval), 4 points
    assert_eq!(a.at("events").at("spans").as_f64(), Some(28.0));
    assert_eq!(a.at("events").at("points").as_f64(), Some(4.0));

    // step wall-clock: 1.0, 1.1, 0.95 ms
    let steps = a.at("steps");
    assert_eq!(steps.at("count").as_f64(), Some(3.0));
    assert!(close(steps.at("total_ms").as_f64().unwrap(), 3.05));
    assert!(close(steps.at("p50_ms").as_f64().unwrap(), 1.0));
    assert!(close(steps.at("p95_ms").as_f64().unwrap(), 1.09));
    assert!(close(steps.at("max_ms").as_f64().unwrap(), 1.1));

    // phase self-time breakdown (totals across all three steps)
    let phases = a.at("phases");
    assert!(close(phases.at("sample").at("total_ms").as_f64().unwrap(), 0.3));
    assert!(close(phases.at("grad").at("total_ms").as_f64().unwrap(), 1.65));
    assert_eq!(phases.at("grad").at("calls").as_f64(), Some(6.0));
    assert_eq!(phases.at("eval").at("calls").as_f64(), Some(1.0));
    let grad_pct = phases.at("grad").at("pct_of_step").as_f64().unwrap();
    assert!(close(grad_pct, 100.0 * 1.65 / 3.05));

    // critical path: serial sample/commit + slowest worker per step
    let cp = a.at("critical_path");
    assert!(close(cp.at("sample_ms").as_f64().unwrap(), 0.3));
    assert!(close(cp.at("compute_ms").as_f64().unwrap(), 2.1));
    assert!(close(cp.at("commit_ms").as_f64().unwrap(), 0.36));
    assert!(close(cp.at("critical_ms").as_f64().unwrap(), 2.76));
    assert!(close(cp.at("stall_ms").as_f64().unwrap(), 0.29));
    // every fixture step has a positive residual — nothing clamped
    assert_eq!(cp.at("clamped_steps").as_f64(), Some(0.0));

    // span-attributed worker busy + imbalance
    let w = a.at("workers");
    assert_eq!(w.at("count").as_f64(), Some(2.0));
    let busy = w.at("busy_ms").as_arr().unwrap();
    assert!(close(busy[0].as_f64().unwrap(), 2.1));
    assert!(close(busy[1].as_f64().unwrap(), 1.65));
    let imb = w.at("imbalance_pct").as_f64().unwrap();
    assert!(close(imb, 100.0 * (1.0 - 1.875 / 2.1)));

    // top-k: step 4 is slowest, grad-dominated at 550/1100 µs
    let top = a.at("top_steps").as_arr().unwrap();
    assert_eq!(top.len(), 2);
    assert_eq!(top[0].at("step").as_f64(), Some(4.0));
    assert!(close(top[0].at("dur_ms").as_f64().unwrap(), 1.1));
    assert_eq!(top[0].at("dominant_phase").as_str(), Some("grad"));
    assert!(close(top[0].at("dominant_pct").as_f64().unwrap(), 50.0));
    assert_eq!(top[1].at("step").as_f64(), Some(0.0));

    // staleness EWMA: each row carries the *prior* epoch's EWMA — the
    // baseline its mean was compared against (epoch 2's is epoch 1's
    // seed 2.0, not the post-fold 2.3); no drift warning (3.0 is
    // exactly the 1.5× threshold, which must not fire)
    let st = a.at("staleness");
    let eps = st.at("epochs").as_arr().unwrap();
    assert_eq!(eps.len(), 2);
    assert!(close(eps[0].at("ewma").as_f64().unwrap(), 2.0));
    assert!(close(eps[1].at("ewma").as_f64().unwrap(), 2.0));
    assert!(st.at("warnings").as_arr().unwrap().is_empty());

    // SED drop-rate from cumulative counters: 0.5, then 65/120
    let sed = a.at("sed");
    let eps = sed.at("epochs").as_arr().unwrap();
    assert!(close(eps[0].at("drop_rate").as_f64().unwrap(), 0.5));
    assert!(close(eps[1].at("drop_rate").as_f64().unwrap(), 65.0 / 120.0));
    assert!(sed.at("warnings").as_arr().unwrap().is_empty());
}

#[test]
fn trace_analysis_is_deterministic() {
    let text = fixture();
    let a = analyze_trace(&text, 3).unwrap().to_string();
    let b = analyze_trace(&text, 3).unwrap().to_string();
    assert_eq!(a, b);
}

#[test]
fn malformed_trace_lines_are_loud() {
    assert!(analyze_trace("{not json", 5).is_err());
    let missing_phase = r#"{"ev":"span","dur_us":10}"#;
    assert!(analyze_trace(missing_phase, 5).is_err());
    // unknown event kinds and blank lines are tolerated
    let odd = "\n{\"ev\":\"other\",\"x\":1}\n";
    assert!(analyze_trace(odd, 5).is_ok());
}

#[test]
fn committed_baseline_passes_against_itself() {
    let base = baseline();
    let d = diff_reports(&base, &base, 20.0).unwrap();
    assert_eq!(d.at("pass").as_bool(), Some(true), "{d:?}");
    assert!(d.at("regressions").as_arr().unwrap().is_empty());
    // the baseline actually exercises the v2-only gate fields
    let fields = d.at("fields").as_arr().unwrap();
    let names: Vec<&str> = fields
        .iter()
        .map(|f| f.at("field").as_str().unwrap())
        .collect();
    assert!(names.contains(&"steps.steady_mean_ms"));
    assert!(names.contains(&"workers.imbalance_pct"));
    assert!(names.contains(&"contention.total_wait_ms"));
    assert!(names.contains(&"caches.fill.hit_rate"));
}

#[test]
fn injected_step_regression_fails_the_gate() {
    let base = baseline();
    // candidate: steady mean inflated 25% (the CI gate's injection)
    let text = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/baselines/report_baseline.json"
    ))
    .unwrap()
    .replace("\"steady_mean_ms\":13.0", "\"steady_mean_ms\":16.25");
    let cand = Json::parse(&text).unwrap();
    let d = diff_reports(&base, &cand, 20.0).unwrap();
    assert_eq!(d.at("pass").as_bool(), Some(false));
    let regs = d.at("regressions").as_arr().unwrap();
    assert_eq!(regs.len(), 1);
    assert_eq!(regs[0].as_str(), Some("steps.steady_mean_ms"));
}

#[test]
fn base_below_floor_blowup_fails_the_gate() {
    // regression: with the base zeroed (below the 0.05 ms floor) a
    // candidate at 50 ms used to sail through — no relative delta means
    // no relative verdict, so only the absolute fallback catches it
    let text = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/baselines/report_baseline.json"
    ))
    .unwrap();
    let base = Json::parse(
        &text.replace(
            "\"table_writeback_ms\":10.0",
            "\"table_writeback_ms\":0.0",
        ),
    )
    .unwrap();
    let cand = Json::parse(
        &text.replace(
            "\"table_writeback_ms\":10.0",
            "\"table_writeback_ms\":50.0",
        ),
    )
    .unwrap();
    let d = diff_reports(&base, &cand, 20.0).unwrap();
    assert_eq!(d.at("pass").as_bool(), Some(false));
    let regs = d.at("regressions").as_arr().unwrap();
    assert_eq!(
        regs[0].as_str(),
        Some("contention.table_writeback_ms")
    );
    // the zeroed base still self-passes
    let d = diff_reports(&base, &base, 20.0).unwrap();
    assert_eq!(d.at("pass").as_bool(), Some(true), "{d:?}");
}

#[test]
fn trace_diff_localizes_the_injected_commit_slowdown() {
    // the regressed fixture inflates table_commit in steps 4 and 8
    // (indices 1–2) only; the diff must name exactly that range and
    // that phase
    let d = diff_traces(&fixture(), &regressed_fixture(), 20.0).unwrap();
    assert_eq!(d.at("schema").as_str(), Some("gst-trace-diff/v1"));
    assert_eq!(d.at("steps").at("compared").as_f64(), Some(3.0));
    assert_eq!(d.at("steps").at("regressed").as_f64(), Some(2.0));
    let hs = d.at("hotspots").as_arr().unwrap();
    assert_eq!(hs.len(), 1);
    assert_eq!(hs[0].at("start_step").as_f64(), Some(4.0));
    assert_eq!(hs[0].at("end_step").as_f64(), Some(8.0));
    assert_eq!(hs[0].at("start_index").as_f64(), Some(1.0));
    assert_eq!(hs[0].at("end_index").as_f64(), Some(2.0));
    assert_eq!(hs[0].at("dominant_phase").as_str(), Some("table_commit"));
    // commit grew (700−130) + (640−110) = 1100 µs = 1.1 ms
    assert!(close(hs[0].at("delta_ms").as_f64().unwrap(), 1.1));
    assert!(close(
        hs[0].at("dominant_delta_ms").as_f64().unwrap(),
        1.1
    ));
    // the commit critical-path leg carries the whole delta
    assert!(close(
        d.at("critical_path").at("commit_delta_ms").as_f64().unwrap(),
        1.1
    ));
    let text = render_trace_diff(&d);
    assert!(text.contains("table_commit"));
    assert!(text.contains("steps 4..8"));
    // identical traces: nothing regressed, no hotspots
    let d = diff_traces(&fixture(), &fixture(), 20.0).unwrap();
    assert_eq!(d.at("steps").at("regressed").as_f64(), Some(0.0));
    assert!(d.at("hotspots").as_arr().unwrap().is_empty());
}

#[test]
fn committed_trend_ring_is_analyzable() {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/baselines/trend_ring.json"
    );
    let ring = Json::parse(&std::fs::read_to_string(path).unwrap())
        .expect("committed ring parses");
    assert_eq!(ring.at("schema").as_str(), Some("gst-trend-ring/v1"));
    let a = trend_analyze(&ring).unwrap();
    assert_eq!(a.at("schema").as_str(), Some("gst-trend-analysis/v1"));
    assert!(a.at("entries").as_f64().unwrap() >= 1.0);
    // the seed entry samples the committed baseline's headline numbers
    let steady = a.at("fields").at("steady_mean_ms");
    assert_eq!(steady.at("first").as_f64(), Some(13.0));
    // a single seed can never warn about drift
    assert!(a.at("warnings").as_arr().unwrap().is_empty());
}
