//! Fixed-bucket histogram for staleness and latency distributions.

use crate::util::json::Json;

/// Fixed-bucket histogram: `edges` are strictly-ascending **inclusive**
/// upper bounds; the counts vector carries one extra overflow bucket at
/// the end, so `counts.len() == edges.len() + 1`.
#[derive(Clone, Debug)]
pub struct Histogram {
    edges: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    pub fn new(edges: Vec<f64>) -> Histogram {
        assert!(
            edges.windows(2).all(|w| w[0] < w[1]),
            "histogram edges must be strictly ascending"
        );
        let n = edges.len() + 1;
        Histogram {
            edges,
            counts: vec![0; n],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Power-of-two staleness buckets in optimizer steps:
    /// ≤0, ≤1, ≤2, ≤4, …, ≤4096, then overflow.
    pub fn staleness() -> Histogram {
        let mut edges = vec![0.0];
        let mut e = 1.0;
        while e <= 4096.0 {
            edges.push(e);
            e *= 2.0;
        }
        Histogram::new(edges)
    }

    pub fn observe(&mut self, v: f64) {
        let i = self.edges.partition_point(|&e| e < v);
        self.counts[i] += 1;
        self.total += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Estimate the `q`-quantile (`q` in [0, 1], clamped) by linear
    /// interpolation inside the bucket holding the target rank. The
    /// observed `min`/`max` bound the estimate, so `q = 0` returns the
    /// minimum, `q = 1` the maximum, and overflow-bucket estimates never
    /// exceed the largest observed value. Empty histograms return 0.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = q * self.total as f64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let before = cum as f64;
            cum += c;
            if cum as f64 >= target {
                let lo = if i == 0 { self.min } else { self.edges[i - 1] };
                let hi = if i < self.edges.len() {
                    self.edges[i]
                } else {
                    self.max
                };
                let lo = lo.clamp(self.min, self.max);
                let hi = hi.clamp(self.min, self.max);
                let frac = (target - before) / c as f64;
                return (lo + (hi - lo) * frac).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fold `other`'s observations into `self`. Both histograms must
    /// share the same edge vector (merging across bucketings would have
    /// no well-defined counts); panics otherwise.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(
            self.edges, other.edges,
            "histogram edges must match to merge"
        );
        for (a, &b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        if other.total > 0 {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "edges",
                Json::arr(self.edges.iter().map(|&e| Json::num(e))),
            ),
            (
                "counts",
                Json::arr(
                    self.counts.iter().map(|&c| Json::num(c as f64)),
                ),
            ),
            ("count", Json::num(self.total as f64)),
            ("mean", Json::num(self.mean())),
            ("max", Json::num(self.max())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_use_inclusive_upper_bounds() {
        let mut h = Histogram::new(vec![1.0, 2.0, 4.0]);
        h.observe(0.5); // -> bucket 0
        h.observe(1.0); // inclusive upper bound -> bucket 0
        h.observe(1.5); // -> bucket 1
        h.observe(4.0); // -> bucket 2
        h.observe(9.0); // -> overflow
        assert_eq!(h.counts(), &[2, 1, 1, 1]);
        assert_eq!(h.count(), 5);
        assert!((h.mean() - 16.0 / 5.0).abs() < 1e-12);
        assert_eq!(h.min(), 0.5);
        assert_eq!(h.max(), 9.0);
    }

    #[test]
    fn empty_histogram_reports_zeroes() {
        let h = Histogram::new(vec![1.0]);
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.to_json().at("count").as_f64(), Some(0.0));
    }

    #[test]
    fn quantile_on_empty_histogram_is_zero() {
        let h = Histogram::new(vec![1.0, 2.0]);
        assert_eq!(h.quantile(0.0), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.quantile(1.0), 0.0);
    }

    #[test]
    fn quantile_extremes_hit_observed_min_and_max() {
        let mut h = Histogram::new(vec![1.0, 2.0, 4.0]);
        for v in [0.5, 1.5, 3.0, 9.0] {
            h.observe(v);
        }
        assert_eq!(h.quantile(0.0), 0.5);
        assert_eq!(h.quantile(1.0), 9.0);
        // out-of-range q clamps instead of extrapolating
        assert_eq!(h.quantile(-1.0), 0.5);
        assert_eq!(h.quantile(2.0), 9.0);
    }

    #[test]
    fn quantile_is_monotonic_and_bounded() {
        let mut h = Histogram::staleness();
        for v in [0.0, 1.0, 1.0, 3.0, 7.0, 90.0, 5000.0] {
            h.observe(v);
        }
        let mut prev = f64::NEG_INFINITY;
        for i in 0..=20 {
            let q = h.quantile(i as f64 / 20.0);
            assert!(q >= prev, "quantile not monotonic at {i}");
            assert!((0.0..=5000.0).contains(&q));
            prev = q;
        }
    }

    #[test]
    fn quantile_single_value_collapses() {
        let mut h = Histogram::new(vec![10.0, 20.0]);
        h.observe(15.0);
        for q in [0.0, 0.25, 0.5, 1.0] {
            assert_eq!(h.quantile(q), 15.0);
        }
    }

    #[test]
    fn quantile_interpolates_within_a_bucket() {
        // 4 observations all in the (1, 2] bucket of known span
        let mut h = Histogram::new(vec![1.0, 2.0]);
        for v in [1.2, 1.4, 1.6, 2.0] {
            h.observe(v);
        }
        // target rank 2 of 4 -> halfway through the bucket [min, 2.0]
        let q50 = h.quantile(0.5);
        assert!(q50 > 1.2 && q50 <= 2.0, "q50 = {q50}");
    }

    #[test]
    fn merge_accumulates_counts_and_extremes() {
        let mut a = Histogram::new(vec![1.0, 2.0]);
        a.observe(0.5);
        a.observe(1.5);
        let mut b = Histogram::new(vec![1.0, 2.0]);
        b.observe(9.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.counts(), &[1, 1, 1]);
        assert_eq!(a.min(), 0.5);
        assert_eq!(a.max(), 9.0);
        assert!((a.mean() - 11.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn merge_with_empty_is_identity_both_ways() {
        let mut a = Histogram::new(vec![1.0]);
        a.observe(0.5);
        let empty = Histogram::new(vec![1.0]);
        a.merge(&empty);
        assert_eq!(a.count(), 1);
        assert_eq!(a.min(), 0.5);
        assert_eq!(a.max(), 0.5);
        let mut e = Histogram::new(vec![1.0]);
        e.merge(&a);
        assert_eq!(e.count(), 1);
        assert_eq!(e.min(), 0.5);
        // merging two empties stays a well-formed empty histogram
        let mut x = Histogram::new(vec![1.0]);
        x.merge(&Histogram::new(vec![1.0]));
        assert_eq!(x.count(), 0);
        assert_eq!(x.min(), 0.0);
        assert_eq!(x.quantile(0.5), 0.0);
    }

    #[test]
    #[should_panic(expected = "edges must match")]
    fn merge_rejects_mismatched_edges() {
        let mut a = Histogram::new(vec![1.0, 2.0]);
        let b = Histogram::new(vec![1.0, 3.0]);
        a.merge(&b);
    }

    #[test]
    fn quantile_is_monotone_in_q_over_random_observations() {
        // property: for any observation set, quantile(q) must be
        // non-decreasing in q and bounded by [min, max] — the seeded
        // PCG stream keeps the "random" inputs reproducible
        let mut rng = crate::util::rng::Pcg64::new(42, 7);
        for round in 0..5 {
            let mut h = Histogram::staleness();
            let n = 20 + round * 40;
            for _ in 0..n {
                // spread across buckets and into overflow
                h.observe((rng.f64() * 6000.0).floor());
            }
            let mut prev = f64::NEG_INFINITY;
            for i in 0..=100 {
                let q = h.quantile(i as f64 / 100.0);
                assert!(
                    q >= prev,
                    "round {round}: quantile not monotone at q={}",
                    i as f64 / 100.0
                );
                assert!(
                    (h.min()..=h.max()).contains(&q),
                    "round {round}: q outside [min, max]"
                );
                prev = q;
            }
            assert_eq!(h.quantile(0.0), h.min());
            assert_eq!(h.quantile(1.0), h.max());
        }
    }

    #[test]
    fn merge_preserves_quantile_bounds_over_random_shards() {
        // property: merged quantiles stay inside the combined [min,
        // max] envelope and the extremes are exactly the shard extremes
        let mut rng = crate::util::rng::Pcg64::new(9, 3);
        for round in 0..5 {
            let mut merged = Histogram::staleness();
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for _ in 0..3 {
                let mut shard = Histogram::staleness();
                for _ in 0..(10 + round * 10) {
                    let v = (rng.f64() * 5000.0).floor();
                    shard.observe(v);
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
                merged.merge(&shard);
            }
            assert_eq!(merged.min(), lo, "round {round}");
            assert_eq!(merged.max(), hi, "round {round}");
            for i in 0..=20 {
                let q = merged.quantile(i as f64 / 20.0);
                assert!(
                    (lo..=hi).contains(&q),
                    "round {round}: merged quantile {q} outside \
                     [{lo}, {hi}]"
                );
            }
            assert_eq!(merged.quantile(0.0), lo);
            assert_eq!(merged.quantile(1.0), hi);
        }
    }

    #[test]
    fn staleness_buckets_cover_powers_of_two() {
        let h = Histogram::staleness();
        // edges 0, 1, 2, 4, ..., 4096 -> 14 edges, 15 buckets
        let j = h.to_json();
        assert_eq!(j.at("edges").as_arr().unwrap().len(), 14);
        assert_eq!(j.at("counts").as_arr().unwrap().len(), 15);
        let mut h = h;
        h.observe(3.0);
        h.observe(5000.0);
        // 3 lands in the ≤4 bucket (index 3), 5000 overflows
        assert_eq!(h.counts()[3], 1);
        assert_eq!(h.counts()[14], 1);
    }
}
