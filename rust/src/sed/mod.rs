//! Stale Embedding Dropout (paper §3.4, Eq. 1).
//!
//! For a graph with J segments of which S are sampled for backprop, with
//! keep probability p, each segment's aggregation weight η is:
//!
//! ```text
//! η = p + (1-p)·J/S          for sampled (fresh) segments
//! η = 0    with prob (1-p)   for stale segments (dropped)
//! η = 1    with prob p       for stale segments (kept)
//! ```
//!
//! Theorem 4.1: this reduces the staleness bias term by a factor of p while
//! adding a dropout-style regularizer. p=1 degrades to plain GST+E; p=0
//! degrades to GST-One. The trainer folds these weights into the
//! `stale_sum`/`eta_s` inputs of the AOT `grad_step`, so the L2 graph never
//! sees p.

use crate::util::rng::Pcg64;

/// The η weights for one graph's segments at one training step.
#[derive(Clone, Debug, PartialEq)]
pub struct SedWeights {
    /// Weight of each sampled (fresh) segment.
    pub eta_fresh: f32,
    /// Weight of each stale segment (0.0 = dropped, 1.0 = kept).
    pub eta_stale: Vec<f32>,
}

/// Draw SED weights. `j` = total segments, `sampled` = indices of the S
/// segments receiving gradients, `p` = keep probability.
pub fn draw(
    j: usize,
    sampled: &[usize],
    p: f32,
    rng: &mut Pcg64,
) -> SedWeights {
    assert!(!sampled.is_empty() && sampled.len() <= j);
    assert!((0.0..=1.0).contains(&p));
    let s = sampled.len();
    let eta_fresh = p + (1.0 - p) * (j as f32) / (s as f32);
    let mut eta_stale = vec![0.0f32; j];
    for (idx, slot) in eta_stale.iter_mut().enumerate() {
        if sampled.contains(&idx) {
            *slot = 0.0; // fresh segments use eta_fresh, not this array
        } else {
            *slot = if rng.coin(p as f64) { 1.0 } else { 0.0 };
        }
    }
    SedWeights { eta_fresh, eta_stale }
}

/// The no-SED (GST+E) weights: every stale segment kept with weight 1 and
/// fresh segments weight 1 — the p=1 limiting case.
pub fn keep_all(j: usize, sampled: &[usize]) -> SedWeights {
    let mut eta_stale = vec![1.0f32; j];
    for &s in sampled {
        eta_stale[s] = 0.0;
    }
    SedWeights { eta_fresh: 1.0, eta_stale }
}

/// GST-One weights: drop every stale segment (p=0 limiting case). The
/// fresh up-weight J/S makes the mean-pooled embedding an unbiased
/// magnitude estimate.
pub fn drop_all(j: usize, sampled: &[usize]) -> SedWeights {
    let s = sampled.len();
    SedWeights {
        eta_fresh: (j as f32) / (s as f32),
        eta_stale: vec![0.0; j],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{forall, zip, Gen};

    #[test]
    fn eq1_fresh_weight() {
        let mut rng = Pcg64::new(0, 0);
        let w = draw(8, &[3], 0.5, &mut rng);
        assert!((w.eta_fresh - (0.5 + 0.5 * 8.0)).abs() < 1e-6);
        assert_eq!(w.eta_stale[3], 0.0);
    }

    #[test]
    fn limiting_cases_match_paper() {
        let mut rng = Pcg64::new(1, 1);
        // p=1 -> GST+E
        let w = draw(6, &[0], 1.0, &mut rng);
        assert_eq!(w.eta_fresh, 1.0);
        assert!(w.eta_stale[1..].iter().all(|&e| e == 1.0));
        assert_eq!(w, keep_all(6, &[0]));
        // p=0 -> GST-One
        let w = draw(6, &[2], 0.0, &mut rng);
        assert_eq!(w.eta_fresh, 6.0);
        assert!(w.eta_stale.iter().all(|&e| e == 0.0));
        assert_eq!(w, drop_all(6, &[2]));
    }

    #[test]
    fn keep_rate_matches_p() {
        let mut rng = Pcg64::new(2, 2);
        let p = 0.3f32;
        let trials = 4000;
        let mut kept = 0usize;
        for _ in 0..trials {
            let w = draw(10, &[0], p, &mut rng);
            kept += w.eta_stale[1..].iter().filter(|&&e| e == 1.0).count();
        }
        let rate = kept as f64 / (trials * 9) as f64;
        assert!((rate - 0.3).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn prop_expected_total_weight_is_j() {
        // E[η_fresh·S + Σ stale η] = S(p + (1-p)J/S) + (J-S)p
        //                          = Sp + (1-p)J + Jp - Sp = J.
        // (This is what keeps the mean-pooled estimate unbiased in scale.)
        forall(
            "E[sum eta] == J",
            6,
            zip(Gen::usize(2..12), Gen::usize(1..100)),
            |&(j, pseed)| {
                let p = (pseed % 100) as f32 / 100.0;
                let mut rng = Pcg64::new(pseed as u64, 9);
                let trials = 6000;
                let mut total = 0f64;
                for _ in 0..trials {
                    let w = draw(j, &[0], p, &mut rng);
                    total += w.eta_fresh as f64
                        + w.eta_stale.iter().map(|&e| e as f64).sum::<f64>();
                }
                let mean = total / trials as f64;
                (mean - j as f64).abs() < 0.25 * (j as f64).sqrt()
            },
        );
    }

    #[test]
    fn multi_segment_sampling() {
        let mut rng = Pcg64::new(3, 3);
        let w = draw(9, &[1, 4, 7], 0.5, &mut rng);
        assert!((w.eta_fresh - (0.5 + 0.5 * 3.0)).abs() < 1e-6);
        for &s in &[1usize, 4, 7] {
            assert_eq!(w.eta_stale[s], 0.0);
        }
    }

    #[test]
    #[should_panic]
    fn empty_sample_panics() {
        let mut rng = Pcg64::new(0, 0);
        draw(4, &[], 0.5, &mut rng);
    }
}
