//! Vertex-cut partitioners: Random, DBH, NE (the paper's Table 6, rows 4-6).
//!
//! Vertex-cut assigns *edges* to segments and replicates endpoint nodes as
//! needed. In theory this loses less structure than edge-cut (the paper's
//! App. C discussion); empirically all locality-preserving methods tie.
//!
//! * Random — each edge to a uniform part.
//! * DBH (Xie et al. '14) — hash the lower-degree endpoint: high-degree
//!   hubs get replicated, low-degree nodes stay intact.
//! * NE (Zhang et al. '17) — neighborhood expansion: grow each part from a
//!   seed by repeatedly pulling in the boundary vertex whose edges add the
//!   least replication.
//!
//! All three bound the per-segment *node* count by `max_size` internally
//! (splitting a part's edge set when its vertex support grows too large),
//! so the AOT shape contract holds without the BFS fallback.

use super::SegmentSet;
use crate::graph::Csr;
use crate::util::rng::Pcg64;
use std::collections::HashSet;

/// Pack an assignment of edges->parts into a SegmentSet, splitting any part
/// whose vertex support exceeds `max_size`.
fn finish(
    g: &Csr,
    mut buckets: Vec<Vec<(u32, u32)>>,
    max_size: usize,
) -> SegmentSet {
    buckets.retain(|b| !b.is_empty());
    // split oversize buckets by edge chunks until vertex support fits
    let mut out: Vec<Vec<(u32, u32)>> = Vec::new();
    while let Some(bucket) = buckets.pop() {
        let support = vertex_support(&bucket);
        if support.len() <= max_size {
            out.push(bucket);
        } else {
            let mid = bucket.len() / 2;
            let (a, b) = bucket.split_at(mid);
            buckets.push(a.to_vec());
            buckets.push(b.to_vec());
        }
    }
    // isolated nodes (degree 0) still need a home: group them into their
    // own segments so coverage holds
    let mut covered = vec![false; g.num_nodes()];
    for b in &out {
        for &(u, v) in b {
            covered[u as usize] = true;
            covered[v as usize] = true;
        }
    }
    let isolated: Vec<u32> = (0..g.num_nodes() as u32)
        .filter(|&v| !covered[v as usize])
        .collect();
    let mut segments: Vec<Vec<u32>> =
        out.iter().map(|b| vertex_support(b)).collect();
    let mut edges: Vec<Vec<(u32, u32)>> = out;
    for chunk in isolated.chunks(max_size) {
        segments.push(chunk.to_vec());
        edges.push(Vec::new());
    }
    SegmentSet { segments, edges: Some(edges) }
}

fn vertex_support(edges: &[(u32, u32)]) -> Vec<u32> {
    let mut v: Vec<u32> =
        edges.iter().flat_map(|&(a, b)| [a, b]).collect();
    v.sort_unstable();
    v.dedup();
    v
}

/// Each edge to a uniformly random part.
pub fn random(g: &Csr, max_size: usize, rng: &mut Pcg64) -> SegmentSet {
    let edges = g.edges();
    let k = edge_parts(g, max_size);
    let mut buckets = vec![Vec::new(); k];
    for e in edges {
        buckets[rng.below(k)].push(e);
    }
    finish(g, buckets, max_size)
}

/// Degree-Based Hashing: assign edge (u,v) by hashing its lower-degree
/// endpoint, so hub replication is preferred over leaf replication.
pub fn dbh(g: &Csr, max_size: usize) -> SegmentSet {
    let edges = g.edges();
    let k = edge_parts(g, max_size);
    let mut buckets = vec![Vec::new(); k];
    for (u, v) in edges {
        let key = if g.degree(u as usize) <= g.degree(v as usize) {
            u
        } else {
            v
        };
        buckets[hash_u32(key) as usize % k].push((u, v));
    }
    finish(g, buckets, max_size)
}

/// Neighborhood expansion: grow each part's vertex set greedily from a
/// seed, claiming all still-unassigned edges incident to the chosen vertex;
/// the next vertex is drawn from the part's boundary (smallest unassigned
/// degree first — the simplified NE heuristic).
pub fn ne(g: &Csr, max_size: usize, rng: &mut Pcg64) -> SegmentSet {
    let edge_list = g.edges();
    let m = edge_list.len();
    let budget = edge_budget(g, max_size);
    let edge_id = |u: u32, v: u32| -> usize {
        // binary search in the sorted edge list
        edge_list
            .binary_search(&(u.min(v), u.max(v)))
            .expect("edge exists")
    };
    let mut assigned = vec![false; m];
    let mut n_assigned = 0usize;
    let mut buckets: Vec<Vec<(u32, u32)>> = Vec::new();
    let mut in_part = vec![false; g.num_nodes()];
    while n_assigned < m {
        // pick a seed with unassigned incident edges
        let mut seed = None;
        for _ in 0..32 {
            let v = rng.below(g.num_nodes());
            if g.neighbors(v)
                .iter()
                .any(|&u| !assigned[edge_id(v as u32, u)])
            {
                seed = Some(v);
                break;
            }
        }
        let seed = seed.unwrap_or_else(|| {
            (0..g.num_nodes())
                .find(|&v| {
                    g.neighbors(v)
                        .iter()
                        .any(|&u| !assigned[edge_id(v as u32, u)])
                })
                .expect("unassigned edge must have an endpoint")
        });
        let mut bucket = Vec::new();
        let mut boundary: Vec<u32> = vec![seed as u32];
        let mut part_nodes: HashSet<u32> = HashSet::new();
        in_part.iter_mut().for_each(|x| *x = false);
        while bucket.len() < budget && !boundary.is_empty() {
            // pull the boundary vertex with the fewest unassigned edges
            // (bounded scan keeps this O(boundary))
            let (bi, &v) = boundary
                .iter()
                .enumerate()
                .min_by_key(|(_, &v)| {
                    g.neighbors(v as usize)
                        .iter()
                        .filter(|&&u| !assigned[edge_id(v, u)])
                        .count()
                })
                .unwrap();
            boundary.swap_remove(bi);
            // the part is closed only once its support is FULL — the
            // claim guard below keeps it at max_size even while v's
            // unassigned edges are absorbed (closing at max_size - 1
            // leaves every part one node short)
            if part_nodes.len() >= max_size {
                break;
            }
            part_nodes.insert(v);
            for &u in g.neighbors(v as usize) {
                let eid = edge_id(v, u);
                if !assigned[eid]
                    && (part_nodes.contains(&u)
                        || part_nodes.len() < max_size)
                {
                    assigned[eid] = true;
                    n_assigned += 1;
                    bucket.push((v.min(u), v.max(u)));
                    part_nodes.insert(u);
                    if !in_part[u as usize] {
                        in_part[u as usize] = true;
                        boundary.push(u);
                    }
                    if bucket.len() >= budget {
                        break;
                    }
                }
            }
        }
        if bucket.is_empty() {
            // seed's edges were all claimed under size pressure; claim one
            // edge directly to guarantee progress
            if let Some(eid) = (0..m).find(|&e| !assigned[e]) {
                assigned[eid] = true;
                n_assigned += 1;
                bucket.push(edge_list[eid]);
            }
        }
        buckets.push(bucket);
    }
    finish(g, buckets, max_size)
}

fn edge_parts(g: &Csr, max_size: usize) -> usize {
    g.num_edges().div_ceil(edge_budget(g, max_size)).max(1)
}

/// Edges per part sized so the vertex support lands near max_size: with
/// average degree d, a locality-poor part of E edges touches ~2E vertices;
/// aim E = max_size * d / 3 then rely on `finish` to split stragglers.
fn edge_budget(g: &Csr, max_size: usize) -> usize {
    let avg_deg =
        (2.0 * g.num_edges() as f64 / g.num_nodes().max(1) as f64).max(1.0);
    ((max_size as f64) * avg_deg / 3.0).ceil() as usize
}

fn hash_u32(x: u32) -> u32 {
    let mut h = x.wrapping_mul(0x9e37_79b9);
    h ^= h >> 16;
    h = h.wrapping_mul(0x85eb_ca6b);
    h ^ (h >> 13)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn community_graph() -> Csr {
        let mut b = GraphBuilder::new(120, 0);
        for c in 0..4 {
            let off = c * 30;
            for i in 0..30 {
                for j in i + 1..30 {
                    if (i * 7 + j) % 4 == 0 {
                        b.add_edge(off + i, off + j);
                    }
                }
            }
        }
        for c in 0..3 {
            b.add_edge(c * 30, (c + 1) * 30);
        }
        b.build()
    }

    #[test]
    fn all_vertex_cut_contracts() {
        let g = community_graph();
        let mut rng = Pcg64::new(0, 0);
        for set in [
            random(&g, 40, &mut rng),
            dbh(&g, 40),
            ne(&g, 40, &mut rng),
        ] {
            set.validate(&g, 40).unwrap();
        }
    }

    #[test]
    fn ne_replicates_less_than_random() {
        let g = community_graph();
        let mut rng = Pcg64::new(1, 1);
        let r = random(&g, 40, &mut rng).cut_cost(&g);
        let n = ne(&g, 40, &mut rng).cut_cost(&g);
        assert!(n < r, "ne replicas {n} >= random replicas {r}");
    }

    #[test]
    fn dbh_replicates_hubs_not_leaves() {
        // star: hub 0 with 60 leaves. DBH hashes the leaf (lower degree),
        // so leaves appear once and only the hub is replicated.
        let mut b = GraphBuilder::new(61, 0);
        for leaf in 1..61 {
            b.add_edge(0, leaf);
        }
        let g = b.build();
        let set = dbh(&g, 40);
        set.validate(&g, 40).unwrap();
        let mut leaf_appearances = vec![0usize; 61];
        for seg in &set.segments {
            for &v in seg {
                leaf_appearances[v as usize] += 1;
            }
        }
        for leaf in 1..61 {
            assert_eq!(leaf_appearances[leaf], 1, "leaf {leaf} replicated");
        }
        assert!(leaf_appearances[0] >= 2, "hub not replicated");
    }

    #[test]
    fn ne_parts_fill_to_max_size() {
        // A 48-node path glued to a disjoint 12-clique. The clique
        // inflates the average degree enough that the edge budget never
        // binds on path parts, and path expansion admits exactly one new
        // vertex per boundary pull — so the first path-seeded part grows
        // until the max_size guard stops it, at exactly max_size nodes.
        // The pre-fix guard (`>= max_size - 1`) closed them at 9.
        use crate::testing::prop::{forall, Gen};
        forall(
            "ne fills parts to max_size",
            16,
            Gen::usize(0..1 << 16),
            |&seed| {
                let mut b = GraphBuilder::new(60, 0);
                for i in 0..47 {
                    b.add_edge(i, i + 1);
                }
                for i in 48..60 {
                    for j in i + 1..60 {
                        b.add_edge(i, j);
                    }
                }
                let g = b.build();
                let mut rng = Pcg64::new(seed as u64, 0xec);
                let set = ne(&g, 10, &mut rng);
                if set.validate(&g, 10).is_err() {
                    return false;
                }
                // largest segment made purely of path nodes
                set.segments
                    .iter()
                    .filter(|s| s.iter().all(|&v| v < 48))
                    .map(|s| s.len())
                    .max()
                    == Some(10)
            },
        );
    }

    #[test]
    fn isolated_nodes_covered() {
        let mut b = GraphBuilder::new(10, 0);
        b.add_edge(0, 1); // nodes 2..10 isolated
        let g = b.build();
        let mut rng = Pcg64::new(2, 2);
        for set in
            [random(&g, 4, &mut rng), dbh(&g, 4), ne(&g, 4, &mut rng)]
        {
            set.validate(&g, 4).unwrap();
        }
    }
}
