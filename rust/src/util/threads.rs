//! Scoped fork-join helpers for data-parallel training (tokio is
//! unavailable offline; the trainer's parallelism is synchronous
//! fork-join over worker threads, which matches the paper's synchronous
//! data-parallel SGD anyway — gradients are averaged every step).

/// Run `f(worker_id)` on `n` threads and collect results in worker order.
/// Panics in workers propagate to the caller.
pub fn fork_join<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(n > 0);
    if n == 1 {
        return vec![f(0)];
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> =
            (0..n).map(|i| scope.spawn({ let f = &f; move || f(i) })).collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    })
}

/// Run `f(worker_id, &mut states[worker_id])` on one thread per state slot
/// and collect results in worker order — the fork-join shape `GstCore`
/// uses to give each worker exclusive ownership of its reusable batch
/// buffers while sharing the engine/params/plans by reference.
/// Panics in workers propagate to the caller.
pub fn fork_join_with<S, T, F>(states: &mut [S], f: F) -> Vec<T>
where
    S: Send,
    T: Send,
    F: Fn(usize, &mut S) -> T + Sync,
{
    assert!(!states.is_empty());
    if states.len() == 1 {
        return vec![f(0, &mut states[0])];
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = states
            .iter_mut()
            .enumerate()
            .map(|(i, s)| scope.spawn({ let f = &f; move || f(i, s) }))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("worker panicked"))
            .collect()
    })
}

/// Split `0..len` into `n` contiguous chunks (final chunks may be smaller);
/// used to shard minibatches across data-parallel workers.
pub fn chunk_ranges(len: usize, n: usize) -> Vec<std::ops::Range<usize>> {
    assert!(n > 0);
    let base = len / n;
    let extra = len % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let sz = base + usize::from(i < extra);
        out.push(start..start + sz);
        start += sz;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fork_join_order_and_parallelism() {
        let out = fork_join(4, |i| i * 10);
        assert_eq!(out, vec![0, 10, 20, 30]);
    }

    #[test]
    fn fork_join_single_worker_runs_inline() {
        assert_eq!(fork_join(1, |i| i + 1), vec![1]);
    }

    #[test]
    fn fork_join_with_gives_each_worker_its_state() {
        let mut states = vec![0usize; 4];
        let out = fork_join_with(&mut states, |i, s| {
            *s = i + 1;
            i * 2
        });
        assert_eq!(out, vec![0, 2, 4, 6]);
        assert_eq!(states, vec![1, 2, 3, 4]);
    }

    #[test]
    fn fork_join_with_single_state_runs_inline() {
        let mut states = vec![7usize];
        let out = fork_join_with(&mut states, |_, s| *s + 1);
        assert_eq!(out, vec![8]);
    }

    #[test]
    fn chunks_cover_everything() {
        for len in [0, 1, 7, 16, 33] {
            for n in [1, 2, 4, 5] {
                let ranges = chunk_ranges(len, n);
                assert_eq!(ranges.len(), n);
                let total: usize = ranges.iter().map(|r| r.len()).sum();
                assert_eq!(total, len);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                }
            }
        }
    }

    #[test]
    fn chunks_balanced() {
        let ranges = chunk_ranges(10, 4);
        let sizes: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
    }
}
