//! Cross-module property tests on coordinator invariants: partitioning,
//! segment padding, SED expectation laws, table/staleness accounting —
//! the quickcheck-style suite DESIGN.md §3 promises.

use gst::datasets::malnet::{generate_graph, MalnetSplit};
use gst::datasets::{MalnetDataset, TpuDataset};
use gst::graph::{Csr, GraphBuilder};
use gst::partition::Algorithm;
use gst::segment::{AdjNorm, SegmentedGraph};
use gst::sed;
use gst::table::EmbeddingTable;
use gst::testing::prop::{forall, zip, Gen};
use gst::util::rng::Pcg64;

fn random_graph(seed: u64) -> Csr {
    let mut rng = Pcg64::new(seed, 77);
    generate_graph(MalnetSplit::Tiny, (seed % 5) as u8, &mut rng)
}

#[test]
fn prop_partition_contract_all_algorithms() {
    forall(
        "partition contract",
        10,
        zip(Gen::usize(0..1000), Gen::usize(48..200)),
        |&(seed, max)| {
            let g = random_graph(seed as u64);
            Algorithm::all().iter().all(|alg| {
                let mut rng = Pcg64::new(seed as u64, 5);
                alg.partition(&g, max, &mut rng).validate(&g, max).is_ok()
            })
        },
    );
}

#[test]
fn prop_padded_rowmean_rows_sum_to_one_or_zero() {
    forall("row-mean rows", 8, Gen::usize(0..1000), |&seed| {
        let g = random_graph(seed as u64);
        let mut rng = Pcg64::new(seed as u64, 3);
        let set = Algorithm::MetisLike.partition(&g, 128, &mut rng);
        let sg = SegmentedGraph::new(&g, &set);
        let n = 128;
        let mut nodes = vec![0f32; n * g.feat_dim];
        let mut adj = vec![0f32; n * n];
        let mut mask = vec![0f32; n];
        for s in 0..sg.num_segments() {
            sg.fill_padded(&g, s, AdjNorm::RowMean, n, g.feat_dim, None,
                           &mut nodes, &mut adj, &mut mask);
            for r in 0..n {
                let sum: f32 = adj[r * n..(r + 1) * n].iter().sum();
                // each row sums to 1 (has in-segment neighbors) or 0
                if !(sum.abs() < 1e-4 || (sum - 1.0).abs() < 1e-4) {
                    return false;
                }
                // padded rows must be all-zero
                if mask[r] == 0.0 && sum.abs() > 1e-6 {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_sym_selfloop_is_symmetric() {
    forall("sym adjacency", 8, Gen::usize(0..1000), |&seed| {
        let g = random_graph(seed as u64);
        let mut rng = Pcg64::new(seed as u64, 4);
        let set = Algorithm::EdgeCutBfs.partition(&g, 96, &mut rng);
        let sg = SegmentedGraph::new(&g, &set);
        let n = 96;
        let mut nodes = vec![0f32; n * g.feat_dim];
        let mut adj = vec![0f32; n * n];
        let mut mask = vec![0f32; n];
        sg.fill_padded(&g, 0, AdjNorm::SymSelfLoop, n, g.feat_dim, None,
                       &mut nodes, &mut adj, &mut mask);
        (0..n).all(|i| {
            (0..n).all(|j| (adj[i * n + j] - adj[j * n + i]).abs() < 1e-6)
        })
    });
}

#[test]
fn prop_sed_limiting_cases() {
    forall(
        "sed limits",
        30,
        zip(Gen::usize(2..16), Gen::usize(0..1000)),
        |&(j, seed)| {
            let mut rng = Pcg64::new(seed as u64, 6);
            let s = seed % j;
            let p0 = sed::draw(j, &[s], 0.0, &mut rng);
            let p1 = sed::draw(j, &[s], 1.0, &mut rng);
            p0 == sed::drop_all(j, &[s]) && p1 == sed::keep_all(j, &[s])
        },
    );
}

#[test]
fn prop_table_roundtrip_any_layout() {
    forall(
        "table roundtrip",
        20,
        zip(Gen::vec_usize(1..8, 1..12), Gen::usize(1..64)),
        |&(ref counts, dim)| {
            let mut t = EmbeddingTable::new(counts, dim);
            let mut rng = Pcg64::new(dim as u64, 1);
            for (g, &c) in counts.iter().enumerate() {
                for s in 0..c {
                    let v: Vec<f32> =
                        (0..dim).map(|_| rng.f32()).collect();
                    t.put(g, s, &v, (g * 100 + s) as u32);
                    if t.get(g, s).unwrap() != &v[..] {
                        return false;
                    }
                }
            }
            t.coverage() == 1.0
        },
    );
}

#[test]
fn prop_generators_deterministic_and_bounded() {
    forall("malnet bounded", 6, Gen::usize(0..100), |&seed| {
        let a = MalnetDataset::generate(MalnetSplit::Tiny, 10, seed as u64);
        let b = MalnetDataset::generate(MalnetSplit::Tiny, 10, seed as u64);
        a.graphs.iter().zip(&b.graphs).all(|(x, y)| x == y)
            && a.graphs.iter().all(|g| g.num_nodes() <= 1_200)
    });
}

#[test]
fn prop_tpu_pairmask_consistent_with_runtimes() {
    forall("tpu runtimes", 6, Gen::usize(0..100), |&seed| {
        let d = TpuDataset::generate(2, 6, seed as u64);
        d.graphs.iter().all(|g| {
            g.runtimes.iter().all(|r| r.is_finite() && *r > 0.0)
        })
    });
}

#[test]
fn vertex_cut_segments_cover_every_edge_endpoint() {
    // failure-injection style: a pathological star + chain graph
    let mut b = GraphBuilder::new(40, 0);
    for leaf in 1..30 {
        b.add_edge(0, leaf);
    }
    for i in 30..39 {
        b.add_edge(i, i + 1);
    }
    let g = b.build();
    for alg in [
        Algorithm::VertexCutRandom,
        Algorithm::VertexCutDbh,
        Algorithm::VertexCutNe,
    ] {
        let mut rng = Pcg64::new(1, 1);
        let set = alg.partition(&g, 16, &mut rng);
        set.validate(&g, 16)
            .unwrap_or_else(|e| panic!("{}: {e}", alg.name()));
        let sg = SegmentedGraph::new(&g, &set);
        // every segment's local edges must reference in-range local ids
        for (si, edges) in sg.local_edges.iter().enumerate() {
            let n = sg.segments[si].len();
            for &(u, v) in edges {
                assert!((u as usize) < n && (v as usize) < n);
            }
        }
    }
}

#[test]
fn enormous_segment_request_clamps_to_one_segment() {
    let g = random_graph(5);
    let mut rng = Pcg64::new(0, 0);
    for alg in [Algorithm::MetisLike, Algorithm::Louvain, Algorithm::EdgeCutBfs]
    {
        let set = alg.partition(&g, 1_000_000, &mut rng);
        assert_eq!(set.segments.len(), 1, "{}", alg.name());
        assert_eq!(set.segments[0].len(), g.num_nodes());
    }
}

#[test]
fn max_size_one_is_all_singletons() {
    let g = random_graph(6);
    let mut rng = Pcg64::new(0, 0);
    let set = Algorithm::EdgeCutBfs.partition(&g, 1, &mut rng);
    set.validate(&g, 1).unwrap();
    assert_eq!(set.segments.len(), g.num_nodes());
}
