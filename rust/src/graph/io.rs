//! Binary serialization for graph datasets.
//!
//! Format (little-endian):
//! `magic "GSTG" | u32 version | u32 feat_dim | u32 n | u32 m2 |
//!  offsets (n+1)*u32 | adj m2*u32 | feats n*feat_dim*f32`
//!
//! Dataset files concatenate a `u32 count`, then `count` records of
//! `u32 label_bits(f32 label) | graph`.

use super::Csr;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

const MAGIC: &[u8; 4] = b"GSTG";
const VERSION: u32 = 1;

fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn get_u32(inp: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    inp.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn encode_graph(g: &Csr, out: &mut Vec<u8>) {
    out.extend_from_slice(MAGIC);
    put_u32(out, VERSION);
    put_u32(out, g.feat_dim as u32);
    put_u32(out, g.num_nodes() as u32);
    put_u32(out, g.adj.len() as u32);
    for &o in &g.offsets {
        put_u32(out, o);
    }
    for &a in &g.adj {
        put_u32(out, a);
    }
    for &f in &g.feats {
        out.extend_from_slice(&f.to_le_bytes());
    }
}

pub fn decode_graph(inp: &mut impl Read) -> Result<Csr> {
    let mut magic = [0u8; 4];
    inp.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad magic {magic:?}");
    }
    let version = get_u32(inp)?;
    if version != VERSION {
        bail!("unsupported version {version}");
    }
    let feat_dim = get_u32(inp)? as usize;
    let n = get_u32(inp)? as usize;
    let m2 = get_u32(inp)? as usize;
    let mut offsets = Vec::with_capacity(n + 1);
    for _ in 0..=n {
        offsets.push(get_u32(inp)?);
    }
    let mut adj = Vec::with_capacity(m2);
    for _ in 0..m2 {
        adj.push(get_u32(inp)?);
    }
    let mut feats = vec![0f32; n * feat_dim];
    let mut buf = vec![0u8; n * feat_dim * 4];
    inp.read_exact(&mut buf)?;
    for (i, c) in buf.chunks_exact(4).enumerate() {
        feats[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
    if *offsets.last().unwrap() as usize != adj.len() {
        bail!("corrupt offsets");
    }
    Ok(Csr { offsets, adj, feats, feat_dim })
}

/// Write a labelled dataset to a file.
pub fn save_dataset(path: &str, graphs: &[(Csr, f32)]) -> Result<()> {
    let mut out = Vec::new();
    put_u32(&mut out, graphs.len() as u32);
    for (g, label) in graphs {
        put_u32(&mut out, label.to_bits());
        encode_graph(g, &mut out);
    }
    std::fs::File::create(path)
        .with_context(|| format!("create {path}"))?
        .write_all(&out)?;
    Ok(())
}

/// Read a labelled dataset from a file.
pub fn load_dataset(path: &str) -> Result<Vec<(Csr, f32)>> {
    let mut f =
        std::fs::File::open(path).with_context(|| format!("open {path}"))?;
    let count = get_u32(&mut f)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let label = f32::from_bits(get_u32(&mut f)?);
        out.push((decode_graph(&mut f)?, label));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn sample() -> Csr {
        let mut b = GraphBuilder::new(5, 3);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(3, 4);
        b.set_feat(2, &[1.0, -2.0, 0.5]);
        b.build()
    }

    #[test]
    fn graph_roundtrip() {
        let g = sample();
        let mut buf = Vec::new();
        encode_graph(&g, &mut buf);
        let g2 = decode_graph(&mut buf.as_slice()).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn dataset_roundtrip() {
        let dir = std::env::temp_dir().join("gst_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ds.bin");
        let ds = vec![(sample(), 1.0f32), (sample(), -3.5f32)];
        save_dataset(path.to_str().unwrap(), &ds).unwrap();
        let ds2 = load_dataset(path.to_str().unwrap()).unwrap();
        assert_eq!(ds.len(), ds2.len());
        for ((g, l), (g2, l2)) in ds.iter().zip(&ds2) {
            assert_eq!(g, g2);
            assert_eq!(l, l2);
        }
    }

    #[test]
    fn corrupt_magic_rejected() {
        let mut buf = Vec::new();
        encode_graph(&sample(), &mut buf);
        buf[0] = b'X';
        assert!(decode_graph(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn truncated_rejected() {
        let mut buf = Vec::new();
        encode_graph(&sample(), &mut buf);
        buf.truncate(buf.len() - 3);
        assert!(decode_graph(&mut buf.as_slice()).is_err());
    }
}
