//! Drivers for the paper's figures (2, 3, 4, 5, 6) — each prints the
//! series the figure plots and saves them as JSON for re-plotting.

use super::common::*;
use crate::datasets::malnet::MalnetSplit;
use crate::metrics::Curve;
use crate::train::{Method, TrainConfig};
use crate::util::json::Json;
use anyhow::Result;

fn curve_cfg(env: &Env, method: Method, seed: u64) -> TrainConfig {
    TrainConfig {
        method,
        epochs: env.profile.epochs,
        finetune_epochs: env.profile.finetune_epochs,
        eval_every: 1, // per-epoch resolution for curves
        seed,
        ..TrainConfig::default()
    }
}

fn print_curve(label: &str, c: &Curve) {
    println!("-- {label}");
    println!("{:>6} {:>8} {:>8}", "epoch", "train", "test");
    for i in 0..c.epochs.len() {
        println!(
            "{:>6} {:>8.4} {:>8.4}",
            c.epochs[i], c.train[i], c.test[i]
        );
    }
}

/// Figure 2: GST+EFD accuracy curve on MalNet-Large (SAGE); the finetune
/// phase starts after `epochs` and should close the train/test gap.
pub fn fig2(env: &Env) -> Result<()> {
    let eng = env.engine("malnet_sage_n128")?;
    let data = env.malnet(MalnetSplit::Large, 0);
    let cfg = curve_cfg(env, Method::GstEFD, 0);
    let finetune_at = cfg.epochs;
    let res =
        run_malnet(env, &eng, &data, cfg, "gst+efd/sage/malnet-large")?;
    println!("\n=== Figure 2: GST+EFD curve, finetune starts at epoch {finetune_at} ===");
    print_curve("GST+EFD (SAGE, malnet-large)", &res.curve);
    let path = env.save(
        "fig2",
        Json::obj(vec![
            ("finetune_at", Json::num(finetune_at as f64)),
            ("curve", res.curve.to_json()),
        ]),
    )?;
    println!("saved {path}");
    Ok(())
}

/// Figure 3: SED keep-ratio sweep p ∈ {0, 0.25, 0.5, 0.75, 1.0}.
pub fn fig3(env: &Env) -> Result<()> {
    let eng = env.engine("malnet_sage_n128")?;
    let ps = [0.0f32, 0.25, 0.5, 0.75, 1.0];
    let mut series = Vec::new();
    for &p in &ps {
        let mut vals = Vec::new();
        for seed in 0..env.profile.seeds as u64 {
            let data = env.malnet(MalnetSplit::Large, seed);
            let mut cfg = curve_cfg(env, Method::GstEFD, seed);
            cfg.keep_p = p;
            cfg.eval_every = cfg.epochs;
            let label = format!("p={p}/seed{seed}");
            let res = run_malnet(env, &eng, &data, cfg, &label)?;
            vals.push(res.test_metric);
        }
        series.push((p, vals));
    }
    println!("\n=== Figure 3: SED keep ratio p (GST+EFD, SAGE, malnet-large) ===");
    println!("{:>6} {:>10} {:>8}", "p", "test acc", "std");
    for (p, vals) in &series {
        println!(
            "{:>6.2} {:>10.4} {:>8.4}",
            p,
            crate::util::stats::mean(vals),
            crate::util::stats::stddev(vals)
        );
    }
    let path = env.save(
        "fig3",
        Json::arr(series.iter().map(|(p, vals)| {
            Json::obj(vec![
                ("p", Json::num(*p as f64)),
                ("acc", Json::arr(vals.iter().map(|&v| Json::num(v)))),
            ])
        })),
    )?;
    println!("saved {path}");
    Ok(())
}

/// Figure 4: max-segment-size sweep (separate AOT variants per size).
pub fn fig4(env: &Env) -> Result<()> {
    let sizes = [32usize, 64, 128, 256];
    let mut series = Vec::new();
    for &n in &sizes {
        let eng = env.engine(&format!("malnet_sage_n{n}"))?;
        let mut vals = Vec::new();
        for seed in 0..env.profile.seeds as u64 {
            let data = env.malnet(MalnetSplit::Large, seed);
            let mut cfg = curve_cfg(env, Method::GstEFD, seed);
            cfg.eval_every = cfg.epochs;
            let label = format!("maxseg{n}/seed{seed}");
            let res = run_malnet(env, &eng, &data, cfg, &label)?;
            vals.push(res.test_metric);
        }
        series.push((n, vals));
    }
    println!("\n=== Figure 4: max segment size (GST+EFD, SAGE, malnet-large) ===");
    println!("{:>8} {:>10} {:>8}", "maxseg", "test acc", "std");
    for (n, vals) in &series {
        println!(
            "{:>8} {:>10.4} {:>8.4}",
            n,
            crate::util::stats::mean(vals),
            crate::util::stats::stddev(vals)
        );
    }
    let path = env.save(
        "fig4",
        Json::arr(series.iter().map(|(n, vals)| {
            Json::obj(vec![
                ("max_nodes", Json::num(*n as f64)),
                ("acc", Json::arr(vals.iter().map(|&v| Json::num(v)))),
            ])
        })),
    )?;
    println!("saved {path}");
    Ok(())
}

/// Figure 5: OPA convergence curves on TpuGraphs.
pub fn fig5(env: &Env) -> Result<()> {
    let eng = env.engine("tpu_sage_n128")?;
    let data = env.tpu(0);
    let methods =
        [Method::Gst, Method::GstOne, Method::GstE, Method::GstEFD];
    let mut out = Vec::new();
    println!("\n=== Figure 5: OPA curves on TpuGraphs ===");
    for method in methods {
        let mut cfg = curve_cfg(env, method, 0);
        cfg.epochs = env.profile.tpu_epochs;
        let res = run_tpu(env, &eng, &data, cfg, method.name())?;
        print_curve(method.name(), &res.curve);
        out.push((method.name().to_string(), res.curve));
    }
    let path = env.save(
        "fig5",
        Json::Obj(
            out.into_iter().map(|(k, c)| (k, c.to_json())).collect(),
        ),
    )?;
    println!("saved {path}");
    Ok(())
}

/// Figure 6: accuracy convergence curves on MalNet-Tiny.
pub fn fig6(env: &Env) -> Result<()> {
    let eng = env.engine("malnet_sage_n128")?;
    let data = env.malnet(MalnetSplit::Tiny, 0);
    let methods = [
        Method::FullGraph,
        Method::Gst,
        Method::GstE,
        Method::GstEFD,
    ];
    let mut out = Vec::new();
    println!("\n=== Figure 6: accuracy curves on MalNet-Tiny (SAGE) ===");
    for method in methods {
        match run_malnet(
            env,
            &eng,
            &data,
            curve_cfg(env, method, 0),
            method.name(),
        ) {
            Ok(res) => {
                print_curve(method.name(), &res.curve);
                out.push((method.name().to_string(), res.curve));
            }
            Err(e) if e.to_string().contains("OOM") => {
                println!("-- {} : OOM", method.name());
            }
            Err(e) => return Err(e),
        }
    }
    let path = env.save(
        "fig6",
        Json::Obj(
            out.into_iter().map(|(k, c)| (k, c.to_json())).collect(),
        ),
    )?;
    println!("saved {path}");
    Ok(())
}
