"""L2 model semantics: shapes, masking, losses, Adam, GST aggregation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.config import OptConfig, VariantConfig

jax.config.update("jax_platform_name", "cpu")

CFGS = {
    "gcn": VariantConfig("malnet", "gcn", max_nodes=32, batch=2),
    "sage": VariantConfig("malnet", "sage", max_nodes=32, batch=2),
    "gps": VariantConfig("malnet", "gps", max_nodes=32, batch=2, mp_layers=2),
    "tpu": VariantConfig("tpu", "sage", max_nodes=32, batch=4, feat=24),
}


def _batch(cfg, seed=0, bsz=None):
    rng = np.random.default_rng(seed)
    b = bsz or cfg.batch
    n, f = cfg.max_nodes, cfg.feat
    nodes = rng.normal(scale=0.3, size=(b, n, f)).astype(np.float32)
    adj = rng.uniform(size=(b, n, n)).astype(np.float32) * 0.1
    mask = np.zeros((b, n), np.float32)
    for i in range(b):
        k = rng.integers(4, n)
        mask[i, :k] = 1.0
        nodes[i, k:] = 0.0
        adj[i, k:, :] = 0.0
        adj[i, :, k:] = 0.0
    return jnp.asarray(nodes), jnp.asarray(adj), jnp.asarray(mask)


# -- parameters ---------------------------------------------------------------

@pytest.mark.parametrize("key", list(CFGS))
def test_init_params_deterministic(key):
    cfg = CFGS[key]
    p1, p2 = model.init_params(cfg, seed=0), model.init_params(cfg, seed=0)
    for k in p1:
        np.testing.assert_array_equal(p1[k], p2[k])


def test_head_params_are_malnet_only():
    p = model.init_params(CFGS["sage"])
    hn = model.head_param_names(CFGS["sage"], p)
    assert hn == ["head_alpha", "head_b1", "head_b2", "head_w1", "head_w2"]
    pt = model.init_params(CFGS["tpu"])
    assert model.head_param_names(CFGS["tpu"], pt) == []


# -- embeddings ---------------------------------------------------------------

@pytest.mark.parametrize("key", ["gcn", "sage", "gps"])
def test_segment_embed_shape_and_mask_invariance(key):
    """Padded-node features must not influence the segment embedding."""
    cfg = CFGS[key]
    p = model.init_params(cfg)
    nodes, adj, mask = _batch(cfg)
    h1 = model.segment_embed(cfg, p, nodes, adj, mask)
    assert h1.shape == (cfg.batch, cfg.hidden)
    noise = jnp.asarray(
        np.random.default_rng(9).normal(size=nodes.shape).astype(np.float32))
    nodes2 = nodes + noise * (1.0 - mask[..., None])
    h2 = model.segment_embed(cfg, p, nodes2, adj, mask)
    np.testing.assert_allclose(h1, h2, rtol=1e-4, atol=1e-5)


def test_tpu_segment_embed_is_scalar_runtime():
    cfg = CFGS["tpu"]
    p = model.init_params(cfg)
    nodes, adj, mask = _batch(cfg)
    r = model.segment_embed(cfg, p, nodes, adj, mask)
    assert r.shape == (cfg.batch, 1)


# -- losses -------------------------------------------------------------------

def test_cross_entropy_matches_manual():
    logits = jnp.asarray([[2.0, 0.0, 0.0], [0.0, 3.0, 0.0]])
    labels = jnp.asarray([0, 1], jnp.int32)
    got = model.cross_entropy(logits, labels)
    want = np.mean([-2.0 + np.log(np.exp(2) + 2), -3.0 + np.log(np.exp(3) + 2)])
    assert float(got) == pytest.approx(float(want), rel=1e-5)


def test_pairwise_hinge_perfect_ranking_is_zero():
    yhat = jnp.asarray([3.0, 1.0, 5.0, 2.0])
    pm = np.zeros((4, 4), np.float32)
    # y order equals yhat order, margins > 1 => zero loss
    pm[2, 0] = pm[0, 1] = pm[2, 1] = 1.0
    assert float(model.pairwise_hinge(yhat, jnp.asarray(pm))) == 0.0


def test_pairwise_hinge_penalizes_inversions():
    yhat = jnp.asarray([0.0, 1.0])
    pm = jnp.asarray([[0.0, 1.0], [0.0, 0.0]])  # y_0 > y_1 but yhat_0 < yhat_1
    assert float(model.pairwise_hinge(yhat, pm)) == pytest.approx(2.0)


def test_pairwise_hinge_empty_mask_is_zero():
    assert float(model.pairwise_hinge(jnp.zeros(3), jnp.zeros((3, 3)))) == 0.0


# -- GST aggregation semantics -------------------------------------------------

def test_grad_step_matches_full_step_when_single_segment():
    """A graph with J=1, eta_s=1, stale_sum=0 must equal full-graph math."""
    cfg = CFGS["sage"]
    p = model.init_params(cfg)
    fn_g, in_g, _ = model.build_grad_step(cfg, p)
    names = model.param_order(p)
    nodes, adj, mask = _batch(cfg)
    labels = jnp.asarray([1, 3], jnp.int32)
    stale = jnp.zeros((cfg.batch, cfg.hidden))
    eta = jnp.ones((cfg.batch,))
    invj = jnp.ones((cfg.batch,))
    outs = fn_g(*[p[k] for k in names], nodes, adj, mask, stale, eta, invj,
                labels)
    loss = outs[0]
    # manual: embed -> head -> CE + l2
    h = model.segment_embed(cfg, p, nodes, adj, mask)
    want = model.cross_entropy(model.head_logits(p, h), labels) \
        + model.l2_penalty(p, cfg.opt.weight_decay)
    assert float(loss) == pytest.approx(float(want), rel=1e-5)
    h_s = outs[-1]
    np.testing.assert_allclose(h_s, h, rtol=1e-5, atol=1e-6)


def test_grad_step_stale_sum_gets_no_gradient():
    """Gradient must flow only through the sampled segment: scaling the
    backbone's stale contribution must leave grads w.r.t. stale_sum zero
    (it is an input, not a traced param)."""
    cfg = CFGS["sage"]
    p = model.init_params(cfg)
    names = model.param_order(p)
    fn_g, _, _ = model.build_grad_step(cfg, p)
    nodes, adj, mask = _batch(cfg)
    labels = jnp.asarray([0, 2], jnp.int32)
    stale = jnp.ones((cfg.batch, cfg.hidden)) * 0.3
    eta = jnp.full((cfg.batch,), 1.5)
    invj = jnp.full((cfg.batch,), 0.25)
    outs = fn_g(*[p[k] for k in names], nodes, adj, mask, stale, eta, invj,
                labels)
    grads = outs[1:-1]
    assert len(grads) == len(names)
    assert all(np.isfinite(np.asarray(g)).all() for g in grads)


def test_full_step_seg_mask_ignores_empty_slots():
    cfg = CFGS["sage"]
    p = model.init_params(cfg)
    names = model.param_order(p)
    fn, _, _ = model.build_full_step(cfg, p)
    jm, n, f = model.FULL_JMAX, cfg.max_nodes, cfg.feat
    rng = np.random.default_rng(0)
    nodes = jnp.asarray(rng.normal(size=(jm, n, f)).astype(np.float32))
    adj = jnp.asarray(rng.uniform(size=(jm, n, n)).astype(np.float32) * 0.1)
    mask = jnp.ones((jm, n))
    seg1 = jnp.asarray([1.0, 1.0] + [0.0] * (jm - 2))
    labels = jnp.asarray([2], jnp.int32)
    args = [p[k] for k in names]
    loss1 = fn(*args, nodes, adj, mask, seg1, labels)[0]
    # scribble on the masked-out slots; loss must not change
    nodes2 = nodes.at[2:].set(99.0)
    loss2 = fn(*args, nodes2, adj, mask, seg1, labels)[0]
    assert float(loss1) == pytest.approx(float(loss2), rel=1e-6)


# -- optimizer ----------------------------------------------------------------

def test_apply_step_is_adam():
    cfg = VariantConfig("malnet", "sage", max_nodes=32, batch=2,
                        opt=OptConfig(lr=0.1))
    p = {"w": np.ones((3,), np.float32)}
    fn, _, _ = model.build_apply_step(cfg, p)
    g = jnp.asarray([1.0, -1.0, 0.0])
    zeros = jnp.zeros(3)
    outs = fn(jnp.ones(3), zeros, zeros, g, jnp.asarray(1.0),
              jnp.asarray(0.1))
    p2, m2, v2 = outs
    # bias-corrected first step: update = lr * sign(g) (eps-perturbed)
    np.testing.assert_allclose(p2, [0.9, 1.1, 1.0], rtol=1e-4)
    np.testing.assert_allclose(m2, 0.1 * g, rtol=1e-6)
    np.testing.assert_allclose(v2, 0.001 * g * g, rtol=1e-5)


def test_apply_step_converges_on_quadratic():
    cfg = CFGS["sage"]
    p = {"w": np.asarray([5.0], np.float32)}
    fn, _, _ = model.build_apply_step(cfg, p)
    w = jnp.asarray([5.0])
    m = v = jnp.zeros(1)
    for t in range(1, 200):
        g = 2.0 * w  # d/dw w^2
        w, m, v = fn(w, m, v, g, jnp.asarray(float(t)), jnp.asarray(0.1))
    assert abs(float(w[0])) < 0.2


# -- head finetuning ----------------------------------------------------------

def test_head_grad_step_only_touches_head():
    cfg = CFGS["sage"]
    p = model.init_params(cfg)
    fn, in_specs, out_specs = model.build_head_grad_step(cfg, p)
    hnames = model.head_param_names(cfg, p)
    assert len(in_specs) == len(hnames) + 2
    h = jnp.asarray(np.random.default_rng(0)
                    .normal(size=(cfg.batch, cfg.hidden)).astype(np.float32))
    labels = jnp.asarray([0, 4], jnp.int32)
    outs = fn(*[p[k] for k in hnames], h, labels)
    assert len(outs) == 1 + len(hnames)
    assert np.isfinite(float(outs[0]))


def test_predict_matches_head_logits():
    cfg = CFGS["sage"]
    p = model.init_params(cfg)
    fn, _, _ = model.build_predict(cfg, p)
    hnames = model.head_param_names(cfg, p)
    h = jnp.asarray(np.random.default_rng(1)
                    .normal(size=(cfg.batch, cfg.hidden)).astype(np.float32))
    got = fn(*[p[k] for k in hnames], h)[0]
    np.testing.assert_allclose(got, model.head_logits(p, h), rtol=1e-5)


# -- tpu variant ---------------------------------------------------------------

def test_tpu_grad_step_runs_and_is_finite():
    cfg = CFGS["tpu"]
    p = model.init_params(cfg)
    names = model.param_order(p)
    fn, _, _ = model.build_grad_step(cfg, p)
    nodes, adj, mask = _batch(cfg)
    stale = jnp.zeros((cfg.batch, 1))
    eta = jnp.ones((cfg.batch,))
    invj = jnp.ones((cfg.batch,))
    pm = np.zeros((cfg.batch, cfg.batch), np.float32)
    pm[0, 1] = pm[2, 3] = 1.0
    outs = fn(*[p[k] for k in names], nodes, adj, mask, stale, eta, invj,
              jnp.asarray(pm))
    assert np.isfinite(float(outs[0]))
    assert outs[-1].shape == (cfg.batch, 1)
