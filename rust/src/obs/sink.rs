//! JSONL trace sink (`--trace-out`): one event object per line.
//!
//! Two event shapes, both flat enough to grep:
//!
//! ```text
//! {"depth":0,"dur_us":412,"ev":"span","phase":"fill","step":3,"t_us":..}
//! {"data":{...},"ev":"point","name":"epoch_staleness","t_us":..}
//! ```

use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::Mutex;

use crate::util::json::Json;
use anyhow::{Context, Result};

/// Buffered line-per-event writer shared across worker threads.
pub struct TraceSink {
    w: Mutex<BufWriter<File>>,
}

impl TraceSink {
    pub fn create(path: &str) -> Result<TraceSink> {
        let f = File::create(path)
            .with_context(|| format!("creating trace file {path}"))?;
        Ok(TraceSink { w: Mutex::new(BufWriter::new(f)) })
    }

    /// Append one event; I/O errors are swallowed — tracing must never
    /// fail a run.
    pub fn write(&self, ev: &Json) {
        let line = ev.to_string();
        let mut w = self.w.lock().unwrap();
        let _ = writeln!(w, "{line}");
    }

    pub fn flush(&self) {
        let _ = self.w.lock().unwrap().flush();
    }
}

#[cfg(test)]
mod tests {
    use super::super::{ObsConfig, Phase, Recorder};
    use crate::util::json::Json;

    #[test]
    fn jsonl_events_have_the_documented_shape() {
        let path = std::env::temp_dir()
            .join(format!("gst_obs_sink_{}.jsonl", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let r = Recorder::new(&ObsConfig {
            trace_out: Some(path.clone()),
            ..ObsConfig::default()
        })
        .unwrap();
        r.set_step(7);
        {
            let _outer = r.span(Phase::Step);
            let _inner = r.span(Phase::Fill);
        }
        r.point(
            "epoch_staleness",
            Json::obj(vec![("epoch", Json::num(1.0))]),
        );
        r.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let events: Vec<Json> =
            text.lines().map(|l| Json::parse(l).unwrap()).collect();
        assert_eq!(events.len(), 3);
        // guards drop in reverse declaration order: fill closes first
        assert_eq!(events[0].at("ev").as_str(), Some("span"));
        assert_eq!(events[0].at("phase").as_str(), Some("fill"));
        assert_eq!(events[0].at("step").as_f64(), Some(7.0));
        assert_eq!(events[0].at("depth").as_f64(), Some(1.0));
        assert_eq!(events[1].at("phase").as_str(), Some("step"));
        assert_eq!(events[1].at("depth").as_f64(), Some(0.0));
        assert!(events[1].at("dur_us").as_f64().unwrap() >= 0.0);
        assert_eq!(events[2].at("ev").as_str(), Some("point"));
        assert_eq!(
            events[2].at("name").as_str(),
            Some("epoch_staleness")
        );
        assert_eq!(events[2].at("data").at("epoch").as_f64(), Some(1.0));
    }
}
