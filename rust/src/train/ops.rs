//! Typed wrappers over the AOT function set: buffer packing, parameter
//! threading, Adam state updates. Shared by both dataset trainers.

use crate::runtime::engine::HostArg;
use crate::runtime::{Engine, Manifest, ParamStore};
use anyhow::Result;

/// Reusable input buffers for one `grad_step` batch (B slots). `GstCore`
/// keeps one per worker and reuses it across every step of a run instead
/// of reallocating per step — every region is fully overwritten by the
/// fill path (the `pair` mask is explicitly cleared by the core, since
/// tasks only write its 1-entries). The (nodes, adj, mask) trio doubles
/// as the `embed_fwd` staging area: the fresh-embedding phase finishes
/// before the grad batch is packed, so the two uses never overlap.
pub struct BatchBufs {
    pub nodes: Vec<f32>,
    pub adj: Vec<f32>,
    pub mask: Vec<f32>,
    pub stale: Vec<f32>,
    pub eta: Vec<f32>,
    pub invj: Vec<f32>,
    /// malnet: class labels (len B)
    pub labels: Vec<i32>,
    /// tpu: pairwise ordering mask (len B*B)
    pub pair: Vec<f32>,
}

impl BatchBufs {
    pub fn new(m: &Manifest) -> BatchBufs {
        let (b, n, f) = (m.batch, m.max_nodes, m.feat);
        BatchBufs {
            nodes: vec![0.0; b * n * f],
            adj: vec![0.0; b * n * n],
            mask: vec![0.0; b * n],
            stale: vec![0.0; b * m.table_dim],
            eta: vec![1.0; b],
            invj: vec![1.0; b],
            labels: vec![0; b],
            pair: vec![0.0; b * b],
        }
    }

    /// Mutable view of slot `i`'s (nodes, adj, mask) region.
    pub fn slot(
        &mut self,
        m: &Manifest,
        i: usize,
    ) -> (&mut [f32], &mut [f32], &mut [f32]) {
        let (n, f) = (m.max_nodes, m.feat);
        (
            &mut self.nodes[i * n * f..(i + 1) * n * f],
            &mut self.adj[i * n * n..(i + 1) * n * n],
            &mut self.mask[i * n..(i + 1) * n],
        )
    }
}
/// Output of one grad_step call.
pub struct StepOut {
    pub loss: f32,
    pub grads: Vec<Vec<f32>>,
    /// fresh segment embeddings [B, table_dim] (write-back payload)
    pub h_s: Vec<f32>,
}

fn params_in(ps: &ParamStore) -> Vec<HostArg<'_>> {
    ps.values.iter().map(|v| HostArg::F32(v)).collect()
}

/// `embed_fwd` over one packed batch; returns [B, table_dim].
pub fn embed_fwd(
    eng: &Engine,
    ps: &ParamStore,
    nodes: &[f32],
    adj: &[f32],
    mask: &[f32],
) -> Result<Vec<f32>> {
    let mut inputs = params_in(ps);
    inputs.push(HostArg::F32(nodes));
    inputs.push(HostArg::F32(adj));
    inputs.push(HostArg::F32(mask));
    let out = eng.call_ref("embed_fwd", &inputs)?;
    Ok(out[0].f32s().to_vec())
}

/// One GST gradient step over a packed batch.
pub fn grad_step(eng: &Engine, ps: &ParamStore, bufs: &BatchBufs) -> Result<StepOut> {
    let np = eng.manifest.params.len();
    let mut inputs = params_in(ps);
    inputs.push(HostArg::F32(&bufs.nodes));
    inputs.push(HostArg::F32(&bufs.adj));
    inputs.push(HostArg::F32(&bufs.mask));
    inputs.push(HostArg::F32(&bufs.stale));
    inputs.push(HostArg::F32(&bufs.eta));
    inputs.push(HostArg::F32(&bufs.invj));
    if eng.manifest.dataset == "malnet" {
        inputs.push(HostArg::S32(&bufs.labels));
    } else {
        inputs.push(HostArg::F32(&bufs.pair));
    }
    let out = eng.call_ref("grad_step", &inputs)?;
    Ok(StepOut {
        loss: out[0].f32s()[0],
        grads: out[1..1 + np].iter().map(|t| t.f32s().to_vec()).collect(),
        h_s: out[1 + np].f32s().to_vec(),
    })
}

/// Full Graph Training step over ONE graph's segments (≤ full_jmax slots).
pub fn full_step(
    eng: &Engine,
    ps: &ParamStore,
    nodes: &[f32],
    adj: &[f32],
    mask: &[f32],
    seg_mask: &[f32],
    label: i32,
) -> Result<StepOut> {
    let np = eng.manifest.params.len();
    let label_buf = [label];
    let mut inputs = params_in(ps);
    inputs.push(HostArg::F32(nodes));
    inputs.push(HostArg::F32(adj));
    inputs.push(HostArg::F32(mask));
    inputs.push(HostArg::F32(seg_mask));
    inputs.push(HostArg::S32(&label_buf));
    let out = eng.call_ref("full_step", &inputs)?;
    Ok(StepOut {
        loss: out[0].f32s()[0],
        grads: out[1..1 + np].iter().map(|t| t.f32s().to_vec()).collect(),
        h_s: out[1 + np].f32s().to_vec(),
    })
}

/// Adam apply over the full parameter set; bumps `ps.t`.
pub fn apply(
    eng: &Engine,
    ps: &mut ParamStore,
    grads: &[Vec<f32>],
    lr: f32,
) -> Result<()> {
    apply_named(eng, "apply_step", ps, grads, lr)
}

/// Adam apply over a subset ParamStore via a subset apply function
/// (`head_apply_step`).
pub fn apply_named(
    eng: &Engine,
    fname: &str,
    ps: &mut ParamStore,
    grads: &[Vec<f32>],
    lr: f32,
) -> Result<()> {
    let np = ps.values.len();
    assert_eq!(grads.len(), np);
    ps.t += 1;
    let t_buf = [ps.t as f32];
    let lr_buf = [lr];
    let mut inputs = params_in(ps);
    inputs.extend(ps.m.iter().map(|x| HostArg::F32(x)));
    inputs.extend(ps.v.iter().map(|x| HostArg::F32(x)));
    inputs.extend(grads.iter().map(|g| HostArg::F32(g)));
    inputs.push(HostArg::F32(&t_buf));
    inputs.push(HostArg::F32(&lr_buf));
    let out = eng.call_ref(fname, &inputs)?;
    for i in 0..np {
        ps.values[i].copy_from_slice(out[i].f32s());
        ps.m[i].copy_from_slice(out[np + i].f32s());
        ps.v[i].copy_from_slice(out[2 * np + i].f32s());
    }
    Ok(())
}

/// Head-only gradient step for +F finetuning (malnet).
pub fn head_grad_step(
    eng: &Engine,
    head: &ParamStore,
    h_graph: &[f32],
    labels: &[i32],
) -> Result<(f32, Vec<Vec<f32>>)> {
    let mut inputs = params_in(head);
    inputs.push(HostArg::F32(h_graph));
    inputs.push(HostArg::S32(labels));
    let out = eng.call_ref("head_grad_step", &inputs)?;
    Ok((
        out[0].f32s()[0],
        out[1..].iter().map(|t| t.f32s().to_vec()).collect(),
    ))
}

/// Eval-time head: logits for a batch of aggregated graph embeddings.
pub fn predict(
    eng: &Engine,
    ps: &ParamStore,
    head_idx: &[usize],
    h_graph: &[f32],
) -> Result<Vec<f32>> {
    let mut inputs: Vec<HostArg> = head_idx
        .iter()
        .map(|&i| HostArg::F32(&ps.values[i]))
        .collect();
    inputs.push(HostArg::F32(h_graph));
    let out = eng.call_ref("predict", &inputs)?;
    Ok(out[0].f32s().to_vec())
}

/// Elementwise-average a list of gradient sets (data-parallel reduction).
pub fn average_grads(sets: &[Vec<Vec<f32>>]) -> Vec<Vec<f32>> {
    assert!(!sets.is_empty());
    let mut out = sets[0].clone();
    for set in &sets[1..] {
        for (acc, g) in out.iter_mut().zip(set) {
            for (a, &x) in acc.iter_mut().zip(g) {
                *a += x;
            }
        }
    }
    let k = sets.len() as f32;
    for g in &mut out {
        for a in g {
            *a /= k;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_grads_is_mean() {
        let a = vec![vec![1.0f32, 2.0], vec![10.0]];
        let b = vec![vec![3.0f32, 6.0], vec![20.0]];
        let avg = average_grads(&[a, b]);
        assert_eq!(avg[0], vec![2.0, 4.0]);
        assert_eq!(avg[1], vec![15.0]);
    }

    #[test]
    fn average_single_is_identity() {
        let a = vec![vec![1.5f32]];
        assert_eq!(average_grads(&[a.clone()]), a);
    }
}
