//! Historical embedding table 𝒯 micro-bench: put/get throughput at the
//! shapes the trainer actually uses (the paper's claim that 𝒯 lookups are
//! negligible next to a forward pass — Table 3 discussion).
//!
//!     cargo bench --bench embed_table

#[path = "harness.rs"]
mod harness;

use gst::table::EmbeddingTable;
use harness::Bench;

fn main() {
    // malnet-large-like: 240 graphs x ~24 segments, d=64
    let counts = vec![24usize; 240];
    let dim = 64;
    let h = vec![0.5f32; dim];
    println!("\nembedding table: {} rows x d={dim}\n", 240 * 24);
    let mut t = EmbeddingTable::new(&counts, dim);
    Bench::new("put x 5760 (full refresh sweep)").iters(20).run(|| {
        for g in 0..240 {
            for s in 0..24 {
                t.put(g, s, &h, 1);
            }
        }
    });
    Bench::new("get x 5760 (epoch of stale reads)").iters(20).run(|| {
        let mut acc = 0f32;
        for g in 0..240 {
            for s in 0..24 {
                acc += t.get(g, s).unwrap()[0];
            }
        }
        std::hint::black_box(acc);
    });
    Bench::new("staleness histogram sweep").iters(20).run(|| {
        std::hint::black_box(t.mean_staleness(100));
    });
    println!("\ntable bytes: {} ({:.2} MiB)", t.bytes(),
             t.bytes() as f64 / (1 << 20) as f64);
}
