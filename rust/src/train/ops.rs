//! Typed wrappers over the AOT function set: buffer packing, parameter
//! threading, Adam state updates. Shared by both dataset trainers.

use crate::runtime::engine::HostArg;
use crate::runtime::{Engine, Manifest, ParamStore};
use anyhow::Result;

/// Reusable input buffers for one `grad_step` batch (B slots). `GstCore`
/// keeps one per worker and reuses it across every step of a run instead
/// of reallocating per step — every region is fully overwritten by the
/// fill path (the `pair` mask is explicitly cleared by the core, since
/// tasks only write its 1-entries). The (nodes, adj, mask) trio doubles
/// as the `embed_fwd` staging area: the fresh-embedding phase finishes
/// before the grad batch is packed, so the two uses never overlap.
pub struct BatchBufs {
    pub nodes: Vec<f32>,
    pub adj: Vec<f32>,
    pub mask: Vec<f32>,
    pub stale: Vec<f32>,
    pub eta: Vec<f32>,
    pub invj: Vec<f32>,
    /// malnet: class labels (len B)
    pub labels: Vec<i32>,
    /// tpu: pairwise ordering mask (len B*B)
    pub pair: Vec<f32>,
}

impl BatchBufs {
    pub fn new(m: &Manifest) -> BatchBufs {
        let (b, n, f) = (m.batch, m.max_nodes, m.feat);
        BatchBufs {
            nodes: vec![0.0; b * n * f],
            adj: vec![0.0; b * n * n],
            mask: vec![0.0; b * n],
            stale: vec![0.0; b * m.table_dim],
            eta: vec![1.0; b],
            invj: vec![1.0; b],
            labels: vec![0; b],
            pair: vec![0.0; b * b],
        }
    }

    /// Resident bytes across every region (telemetry gauge).
    pub fn bytes(&self) -> usize {
        4 * (self.nodes.len()
            + self.adj.len()
            + self.mask.len()
            + self.stale.len()
            + self.eta.len()
            + self.invj.len()
            + self.labels.len()
            + self.pair.len())
    }

    /// Mutable view of slot `i`'s (nodes, adj, mask) region.
    pub fn slot(
        &mut self,
        m: &Manifest,
        i: usize,
    ) -> (&mut [f32], &mut [f32], &mut [f32]) {
        let (n, f) = (m.max_nodes, m.feat);
        (
            &mut self.nodes[i * n * f..(i + 1) * n * f],
            &mut self.adj[i * n * n..(i + 1) * n * n],
            &mut self.mask[i * n..(i + 1) * n],
        )
    }
}
/// Output of one grad_step call.
pub struct StepOut {
    pub loss: f32,
    pub grads: Vec<Vec<f32>>,
    /// fresh segment embeddings [B, table_dim] (write-back payload)
    pub h_s: Vec<f32>,
}

impl StepOut {
    /// A zeroed output shaped for `grad_step` under `m` — the reusable
    /// form [`grad_step_into`] fills without reallocating.
    pub fn zeros(m: &Manifest) -> StepOut {
        StepOut {
            loss: 0.0,
            grads: m.params.iter().map(|p| vec![0.0; p.elems()]).collect(),
            h_s: vec![0.0; m.batch * m.table_dim],
        }
    }
}

/// `embed_fwd` over one packed batch; returns [B, table_dim].
///
/// Parameter inputs ride the engine's literal cache
/// ([`Engine::call_with_params`]): the dozens of calls between two
/// optimizer applies marshal the parameter set once.
pub fn embed_fwd(
    eng: &Engine,
    ps: &ParamStore,
    nodes: &[f32],
    adj: &[f32],
    mask: &[f32],
) -> Result<Vec<f32>> {
    let rest = [
        HostArg::F32(nodes),
        HostArg::F32(adj),
        HostArg::F32(mask),
    ];
    let out = eng.call_with_params("embed_fwd", ps, &rest)?;
    Ok(out[0].f32s().to_vec())
}

/// One GST gradient step over a packed batch.
pub fn grad_step(eng: &Engine, ps: &ParamStore, bufs: &BatchBufs) -> Result<StepOut> {
    let np = eng.manifest.params.len();
    let mut rest = vec![
        HostArg::F32(&bufs.nodes),
        HostArg::F32(&bufs.adj),
        HostArg::F32(&bufs.mask),
        HostArg::F32(&bufs.stale),
        HostArg::F32(&bufs.eta),
        HostArg::F32(&bufs.invj),
    ];
    if eng.manifest.dataset == "malnet" {
        rest.push(HostArg::S32(&bufs.labels));
    } else {
        rest.push(HostArg::F32(&bufs.pair));
    }
    let out = eng.call_with_params("grad_step", ps, &rest)?;
    Ok(StepOut {
        loss: out[0].f32s()[0],
        grads: out[1..1 + np].iter().map(|t| t.f32s().to_vec()).collect(),
        h_s: out[1 + np].f32s().to_vec(),
    })
}

/// [`grad_step`] into a preallocated [`StepOut`] (shaped by
/// [`StepOut::zeros`]) — the steady-state path copies engine outputs in
/// place instead of growing fresh vectors every micro-batch.
pub fn grad_step_into(
    eng: &Engine,
    ps: &ParamStore,
    bufs: &BatchBufs,
    out: &mut StepOut,
) -> Result<()> {
    let np = eng.manifest.params.len();
    let mut rest = vec![
        HostArg::F32(&bufs.nodes),
        HostArg::F32(&bufs.adj),
        HostArg::F32(&bufs.mask),
        HostArg::F32(&bufs.stale),
        HostArg::F32(&bufs.eta),
        HostArg::F32(&bufs.invj),
    ];
    if eng.manifest.dataset == "malnet" {
        rest.push(HostArg::S32(&bufs.labels));
    } else {
        rest.push(HostArg::F32(&bufs.pair));
    }
    let o = eng.call_with_params("grad_step", ps, &rest)?;
    out.loss = o[0].f32s()[0];
    for (dst, src) in out.grads.iter_mut().zip(&o[1..1 + np]) {
        dst.copy_from_slice(src.f32s());
    }
    out.h_s.copy_from_slice(o[1 + np].f32s());
    Ok(())
}

/// Full Graph Training step over ONE graph's segments (≤ full_jmax slots).
pub fn full_step(
    eng: &Engine,
    ps: &ParamStore,
    nodes: &[f32],
    adj: &[f32],
    mask: &[f32],
    seg_mask: &[f32],
    label: i32,
) -> Result<StepOut> {
    let np = eng.manifest.params.len();
    let label_buf = [label];
    let rest = [
        HostArg::F32(nodes),
        HostArg::F32(adj),
        HostArg::F32(mask),
        HostArg::F32(seg_mask),
        HostArg::S32(&label_buf),
    ];
    let out = eng.call_with_params("full_step", ps, &rest)?;
    Ok(StepOut {
        loss: out[0].f32s()[0],
        grads: out[1..1 + np].iter().map(|t| t.f32s().to_vec()).collect(),
        h_s: out[1 + np].f32s().to_vec(),
    })
}

/// Adam apply over the full parameter set; bumps `ps.t`.
pub fn apply(
    eng: &Engine,
    ps: &mut ParamStore,
    grads: &[Vec<f32>],
    lr: f32,
) -> Result<()> {
    apply_named(eng, "apply_step", ps, grads, lr)
}

/// Adam apply over a subset ParamStore via a subset apply function
/// (`head_apply_step`).
pub fn apply_named(
    eng: &Engine,
    fname: &str,
    ps: &mut ParamStore,
    grads: &[Vec<f32>],
    lr: f32,
) -> Result<()> {
    let np = ps.values.len();
    assert_eq!(grads.len(), np);
    ps.t += 1;
    let t_buf = [ps.t as f32];
    let lr_buf = [lr];
    let mut rest: Vec<HostArg> = Vec::with_capacity(3 * np + 2);
    rest.extend(ps.m.iter().map(|x| HostArg::F32(x)));
    rest.extend(ps.v.iter().map(|x| HostArg::F32(x)));
    rest.extend(grads.iter().map(|g| HostArg::F32(g)));
    rest.push(HostArg::F32(&t_buf));
    rest.push(HostArg::F32(&lr_buf));
    let out = eng.call_with_params(fname, ps, &rest)?;
    for i in 0..np {
        ps.values[i].copy_from_slice(out[i].f32s());
        ps.m[i].copy_from_slice(out[np + i].f32s());
        ps.v[i].copy_from_slice(out[2 * np + i].f32s());
    }
    // values changed: invalidate the engine's parameter-literal cache
    ps.touch();
    Ok(())
}

/// Head-only gradient step for +F finetuning (malnet).
pub fn head_grad_step(
    eng: &Engine,
    head: &ParamStore,
    h_graph: &[f32],
    labels: &[i32],
) -> Result<(f32, Vec<Vec<f32>>)> {
    let rest = [HostArg::F32(h_graph), HostArg::S32(labels)];
    let out = eng.call_with_params("head_grad_step", head, &rest)?;
    Ok((
        out[0].f32s()[0],
        out[1..].iter().map(|t| t.f32s().to_vec()).collect(),
    ))
}

/// Eval-time head: logits for a batch of aggregated graph embeddings.
pub fn predict(
    eng: &Engine,
    ps: &ParamStore,
    head_idx: &[usize],
    h_graph: &[f32],
) -> Result<Vec<f32>> {
    let mut inputs: Vec<HostArg> = head_idx
        .iter()
        .map(|&i| HostArg::F32(&ps.values[i]))
        .collect();
    inputs.push(HostArg::F32(h_graph));
    let out = eng.call_ref("predict", &inputs)?;
    Ok(out[0].f32s().to_vec())
}

/// In-place data-parallel gradient reduction. One instance lives for the
/// whole run (owned by `GstCore`), so the per-group clone-then-add of the
/// old `average_grads` becomes add-into-preallocated.
///
/// Usage per optimizer group: `add` each result's gradient set in plan
/// order, then `mean` to finalize and borrow the averaged set. `mean`
/// resets the accumulator for the next group. The k=1 path copies without
/// dividing, so a single-set group reproduces its input bit-for-bit
/// (the old `average_grads` divided by 1.0, also an identity).
pub struct GradAccum {
    acc: Vec<Vec<f32>>,
    count: usize,
}

impl GradAccum {
    /// Buffers sized from the manifest's parameter list.
    pub fn new(m: &Manifest) -> GradAccum {
        GradAccum {
            acc: m.params.iter().map(|p| vec![0f32; p.elems()]).collect(),
            count: 0,
        }
    }

    /// Accumulate one gradient set. The first set of a group overwrites
    /// (no zeroing pass needed); later sets add elementwise.
    pub fn add(&mut self, set: &[Vec<f32>]) {
        assert_eq!(set.len(), self.acc.len());
        if self.count == 0 {
            for (a, g) in self.acc.iter_mut().zip(set) {
                a.copy_from_slice(g);
            }
        } else {
            for (a, g) in self.acc.iter_mut().zip(set) {
                for (x, &y) in a.iter_mut().zip(g) {
                    *x += y;
                }
            }
        }
        self.count += 1;
    }

    /// Finalize the mean in place and borrow it; resets for reuse.
    pub fn mean(&mut self) -> &[Vec<f32>] {
        assert!(self.count > 0, "mean of empty GradAccum");
        if self.count > 1 {
            let k = self.count as f32;
            for g in &mut self.acc {
                for x in g {
                    *x /= k;
                }
            }
        }
        self.count = 0;
        &self.acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::tests_support::tiny_manifest;

    #[test]
    fn grad_accum_is_mean() {
        let m = tiny_manifest(); // params: a (4 elems), head_b (2 elems)
        let mut acc = GradAccum::new(&m);
        acc.add(&[vec![1.0, 2.0, 0.0, 0.0], vec![10.0, 0.0]]);
        acc.add(&[vec![3.0, 6.0, 0.0, 0.0], vec![20.0, 0.0]]);
        let avg = acc.mean();
        assert_eq!(avg[0], vec![2.0, 4.0, 0.0, 0.0]);
        assert_eq!(avg[1], vec![15.0, 0.0]);
    }

    #[test]
    fn grad_accum_single_is_identity_and_reusable() {
        let m = tiny_manifest();
        let mut acc = GradAccum::new(&m);
        let a = vec![vec![1.5f32, 0.0, 0.0, 0.0], vec![0.5, 0.25]];
        acc.add(&a);
        assert_eq!(acc.mean(), &a[..]);
        // mean() reset the accumulator: the next group starts fresh
        let b = vec![vec![7.0f32, 0.0, 0.0, 0.0], vec![1.0, 2.0]];
        acc.add(&b);
        assert_eq!(acc.mean(), &b[..]);
    }
}
