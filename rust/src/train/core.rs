//! The dataset-agnostic GST core: one implementation of the paper's
//! Algorithm 1/2 inner loop shared by every task (DESIGN.md §4).
//!
//! [`GstTask`] is the thin, dataset-specific surface — segment provider,
//! per-slot batch fill, historical-table row mapping, loss-specific
//! buffers, eval hook — while [`GstCore`] owns everything the methods have
//! in common: epoch shuffling, segment sampling, the SED draw, historical
//! table reads / fresh recomputation / write-back, micro-batch gradient
//! averaging, step timing, eval cadence and the +F finetuning phase.
//!
//! # Execution model
//!
//! Each optimization step processes `cfg.micro_batches` micro-batches
//! (simulated data-parallel devices, gradients averaged before one Adam
//! apply) in three phases:
//!
//! 1. **plan** (sequential) — per micro-batch: derive a private RNG stream
//!    keyed by the global step index, let the task describe the batch
//!    slots, sample segments, draw SED weights, and resolve stale reads
//!    against a *snapshot* of the table (the state at group start);
//! 2. **compute** (parallel) — the micro-batches are sharded contiguously
//!    over `cfg.workers` threads via [`fork_join_with`]; each worker owns
//!    a reusable [`BatchBufs`] (staging `embed_fwd` batches and the grad
//!    batch in turn) and drives the shared [`Engine`] (which is `Sync`);
//! 3. **commit** (sequential, in micro-batch order) — table write-backs
//!    (Alg. 2 line 7), gradient averaging, one optimizer apply.
//!
//! Because plans depend only on the step index and the group-start table
//! snapshot, and commits replay in micro-batch order, **the trained
//! parameters are identical for any `cfg.workers` value** — threads are an
//! execution knob, `micro_batches` is the semantic one. The conformance
//! suite pins this (workers=1 vs workers=4, same parameters).

use super::ops::{self, BatchBufs, GradAccum};
use super::{Method, RunResult, SedMode, TrainConfig};
use crate::memory::MemoryModel;
use crate::metrics::{CacheStats, Curve};
use crate::obs::{EpochStats, Histogram, Phase, Recorder};
use crate::runtime::{Engine, ParamStore};
use crate::sed;
use crate::table::EmbeddingTable;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::sync::LockStats;
use crate::util::threads;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::time::Instant;

/// One micro-batch slot, described by the task during the plan phase.
#[derive(Clone, Debug)]
pub struct SlotSpec {
    /// Historical-table row backing this slot (graph, or (graph, config)).
    pub row: usize,
    /// Number of segments J of the slot's parent graph.
    pub num_segments: usize,
    /// Pooling normalization fed to `grad_step`: 1/J (mean pool, MalNet)
    /// or 1.0 (sum pool, TpuGraphs §5.3).
    pub invj: f32,
}

/// Mutable views of the core-owned training state, handed to task hooks
/// that run outside the shared inner loop (FullGraph baseline epochs and
/// the +F finetuning phase).
pub struct CoreEnv<'e> {
    pub eng: &'e Engine,
    pub cfg: &'e TrainConfig,
    pub ps: &'e mut ParamStore,
    pub table: &'e mut EmbeddingTable,
    pub rng: &'e mut Pcg64,
    /// run-wide recorder (step timing, spans, counters — all `&self`)
    pub obs: &'e Recorder,
    pub step: &'e mut u32,
    /// shared in-place gradient reducer (core-owned, reused every group)
    pub accum: &'e mut GradAccum,
}

/// Effective learning rate: config override or the manifest default —
/// the single definition shared by the inner loop, the FullGraph
/// baseline and the finetune phase.
pub fn effective_lr(cfg: &TrainConfig, eng: &Engine) -> f32 {
    cfg.lr.unwrap_or(eng.manifest.lr)
}

impl CoreEnv<'_> {
    /// Effective learning rate (config override or manifest default).
    pub fn lr(&self) -> f32 {
        effective_lr(self.cfg, self.eng)
    }
}

/// Dataset-specific surface of the GST trainer. Implementations are thin
/// (~100 lines): everything method-shaped lives in [`GstCore`].
///
/// `Sync` is required because `fill_slot`/`fill_loss` run concurrently on
/// worker threads during the compute phase (read-only).
pub trait GstTask: Sync {
    /// Per-micro-batch state threaded from [`GstTask::begin_step`] into
    /// the fill callbacks (slot → graph/config resolution, cached
    /// features). Shared read-only across worker threads.
    type StepCtx: Send + Sync;

    /// Manifest `dataset` this task drives (sanity-checked at startup).
    fn dataset(&self) -> &'static str;

    /// RNG stream tag keeping task families decorrelated across datasets.
    fn seed_tag(&self) -> u64;

    /// AOT functions to pre-compile for `method`, so step timings
    /// (Table 3) exclude compilation.
    fn warmup_fns(&self, method: Method) -> Vec<&'static str>;

    /// Historical-table layout: segments per row, in row order.
    fn table_rows(&self) -> Vec<usize>;

    /// Training items (dataset indices), shuffled once per epoch.
    fn train_items(&self) -> &[usize];

    /// Chunk one shuffled item order into micro-batch units (MalNet:
    /// chunks of B graphs, drop-last; TpuGraphs: one graph per unit).
    fn plan_epoch(&self, order: &[usize]) -> Vec<Vec<usize>>;

    /// Describe one micro-batch: build the per-step context and exactly
    /// `manifest.batch` slot specs. Runs sequentially in the plan phase;
    /// any task-side randomness (e.g. config sampling) draws from `rng`,
    /// the step's private stream.
    fn begin_step(
        &mut self,
        unit: &[usize],
        rng: &mut Pcg64,
    ) -> (Self::StepCtx, Vec<SlotSpec>);

    /// Write the loss-specific buffers (`labels` for classification, the
    /// `pair` ordering mask for ranking; `pair` arrives zeroed).
    fn fill_loss(&self, ctx: &Self::StepCtx, bufs: &mut BatchBufs);

    /// Fill the padded (nodes, adj, mask) views with `slot`'s segment
    /// `seg`. Used for both the grad batch (sampled segments) and
    /// `embed_fwd` batches (stale-segment recomputation).
    fn fill_slot(
        &self,
        ctx: &Self::StepCtx,
        slot: usize,
        seg: usize,
        nodes: &mut [f32],
        adj: &mut [f32],
        mask: &mut [f32],
    );

    /// Scalar eval metric over dataset indices (accuracy / OPA).
    fn eval_metric(
        &self,
        eng: &Engine,
        ps: &ParamStore,
        items: &[usize],
    ) -> Result<f64>;

    /// Capped training subset used for the train-side curve points.
    fn eval_train_subset(&self) -> Vec<usize>;

    /// Test-set indices.
    fn test_items(&self) -> &[usize];

    /// Total segments across the dataset (observability).
    fn total_segments(&self) -> usize;

    /// Hit/miss counters of the task's padded fill-block cache, if it
    /// runs one (`cfg.fill_cache_mb`). Default: no cache.
    fn fill_cache_stats(&self) -> CacheStats {
        CacheStats::default()
    }

    /// Bytes held by the task's precomputed fill structures (telemetry
    /// gauge). Default: none.
    fn prepared_bytes(&self) -> usize {
        0
    }

    /// Bytes resident in the task's fill-block cache (telemetry gauge).
    /// Default: no cache.
    fn fill_cache_bytes(&self) -> usize {
        0
    }

    /// Contention counters of task-owned locks (name → stats), merged
    /// into the run report's `contention` section under a `task.`
    /// prefix. Default: no task-side locks.
    fn contention(&self) -> Vec<(String, LockStats)> {
        Vec::new()
    }

    /// Full Graph Training baseline epoch. Default: unsupported (tasks
    /// whose constructor rejects `Method::FullGraph` never reach this).
    fn full_graph_epoch(&mut self, _env: &mut CoreEnv<'_>) -> Result<()> {
        bail!(
            "Full Graph Training is not supported on {}",
            self.dataset()
        )
    }

    /// Prediction Head Finetuning (+F, Alg. 2 lines 11-18). Default:
    /// nothing to finetune (TpuGraphs: F' is a parameter-free sum).
    fn finetune(
        &mut self,
        _env: &mut CoreEnv<'_>,
        _curve: &mut Curve,
        _eval_train: &[usize],
    ) -> Result<()> {
        Ok(())
    }
}

/// Batch-padding rule shared by every `embed_fwd` batching site: a short
/// final chunk fills its trailing slots by repeating the chunk's **last**
/// entry (the repeats' embeddings are discarded, so any in-range segment
/// works; the last one keeps the rule branch-free).
pub fn padded_index(slot: usize, chunk_len: usize) -> usize {
    slot.min(chunk_len - 1)
}

/// SED weights for one slot under `mode` (Eq. 1 and its limiting cases).
fn sed_weights(
    mode: SedMode,
    j: usize,
    s: usize,
    rng: &mut Pcg64,
) -> sed::SedWeights {
    match mode {
        SedMode::KeepAll => sed::keep_all(j, &[s]),
        SedMode::DropAll => sed::drop_all(j, &[s]),
        SedMode::Draw(p) => sed::draw(j, &[s], p, rng),
    }
}

/// Fully-resolved plan for one micro-batch (plan phase output). Immutable
/// and `Sync` during the compute phase.
struct StepPlan<C> {
    ctx: C,
    slots: Vec<SlotSpec>,
    /// sampled segment per slot
    sampled: Vec<usize>,
    /// SED fresh-segment weight per slot
    eta_fresh: Vec<f32>,
    /// [B, table_dim] stale aggregate, table hits pre-accumulated
    stale: Vec<f32>,
    /// stale segments to recompute fresh: (slot, seg, eta)
    fresh: Vec<(usize, usize, f32)>,
    /// global step index — the table write-back version
    step_id: u32,
}

/// Compute-phase output for one micro-batch.
struct StepResult {
    grads: Vec<Vec<f32>>,
    /// fresh sampled-segment embeddings [B, table_dim]
    h_s: Vec<f32>,
    /// one embedding per `plan.fresh` entry, in order
    fresh_embs: Vec<Vec<f32>>,
}

/// The shared GST driver. Owns all cross-step state (parameters, Adam
/// moments, the historical table, RNG, timers); the task contributes only
/// dataset-specific mapping and filling.
pub struct GstCore<'a, T: GstTask> {
    pub task: T,
    eng: &'a Engine,
    pub cfg: TrainConfig,
    pub ps: ParamStore,
    pub table: EmbeddingTable,
    rng: Pcg64,
    step: u32,
    /// optimization steps recorded during epoch 0 (cold-table warmup)
    first_epoch_steps: usize,
    /// observability hub: always-on step timer + opt-in telemetry
    pub obs: Recorder,
    /// one reusable buffer set per worker (embed staging + grad batch)
    bufs: Vec<BatchBufs>,
    /// in-place gradient reducer, reused across every optimizer group
    accum: GradAccum,
    /// cumulative wall-clock of the serial table write-back loop (ns) —
    /// the commit path holds no lock (it has `&mut` on the table), so
    /// its cost is measured directly rather than through a timed lock
    table_writeback_ns: u64,
}

impl<'a, T: GstTask> GstCore<'a, T> {
    /// Wire a task to the shared driver: allocate the table from the
    /// task's row layout, load parameters, pre-compile the method's AOT
    /// functions, and size the per-worker buffer pool.
    pub fn with_task(
        eng: &'a Engine,
        task: T,
        cfg: TrainConfig,
    ) -> Result<GstCore<'a, T>> {
        assert_eq!(eng.manifest.dataset, task.dataset());
        assert_eq!(
            cfg.s_per_graph, 1,
            "the AOT grad_step samples S=1 segment per graph slot \
             (paper's setting)"
        );
        let table =
            EmbeddingTable::new(&task.table_rows(), eng.manifest.table_dim);
        let ps = ParamStore::load(eng.dir(), &eng.manifest)?;
        eng.warmup(&task.warmup_fns(cfg.method))?;
        let pool = cfg.workers.max(1).min(cfg.micro_batches.max(1));
        let bufs: Vec<BatchBufs> =
            (0..pool).map(|_| BatchBufs::new(&eng.manifest)).collect();
        let rng = Pcg64::new(cfg.seed, task.seed_tag());
        let obs = Recorder::new(&cfg.obs)?;
        if obs.is_enabled() {
            let m = &eng.manifest;
            let mm = MemoryModel::for_dataset(&m.dataset, &m.backbone);
            // segment edge counts are not manifest data; 4 × nodes is
            // the synthetic generators' average-degree envelope
            let peak = mm.gst_peak_bytes(
                m.batch,
                cfg.s_per_graph,
                m.max_nodes,
                4 * m.max_nodes,
            );
            obs.gauge("memory_model_peak_bytes", peak as f64);
            obs.gauge(
                "prepared_fill_bytes",
                task.prepared_bytes() as f64,
            );
            obs.gauge(
                "batch_bufs_bytes",
                bufs.iter().map(|b| b.bytes()).sum::<usize>() as f64,
            );
        }
        Ok(GstCore {
            task,
            eng,
            cfg,
            ps,
            table,
            rng,
            step: 0,
            first_epoch_steps: 0,
            obs,
            bufs,
            accum: GradAccum::new(&eng.manifest),
            table_writeback_ns: 0,
        })
    }

    pub fn engine(&self) -> &'a Engine {
        self.eng
    }

    /// Total segments across the dataset (observability).
    pub fn total_segments(&self) -> usize {
        self.task.total_segments()
    }

    /// Global optimization-step counter.
    pub fn steps_done(&self) -> u32 {
        self.step
    }

    /// Split `self` into the task and a [`CoreEnv`] over the remaining
    /// state (disjoint field borrows).
    fn split_env(&mut self) -> (&mut T, CoreEnv<'_>) {
        let GstCore {
            task,
            eng,
            cfg,
            ps,
            table,
            rng,
            obs,
            step,
            accum,
            ..
        } = self;
        (
            task,
            CoreEnv {
                eng: *eng,
                cfg: &*cfg,
                ps,
                table,
                rng,
                obs: &*obs,
                step,
                accum,
            },
        )
    }

    /// Run the full schedule: `epochs` of training, then (for +F methods)
    /// the finetuning phase, recording the metric curve.
    pub fn train(&mut self) -> Result<RunResult> {
        let mut curve = Curve::default();
        let eval_train = self.task.eval_train_subset();
        for epoch in 0..self.cfg.epochs {
            if self.cfg.method == Method::FullGraph {
                let (task, mut env) = self.split_env();
                task.full_graph_epoch(&mut env)?;
            } else {
                self.gst_epoch(epoch)?;
            }
            if epoch == 0 {
                self.first_epoch_steps = self.obs.step_count();
            }
            self.record_epoch_telemetry(epoch + 1);
            if (epoch + 1) % self.cfg.eval_every == 0
                || epoch + 1 == self.cfg.epochs
            {
                let _eval = self.obs.span(Phase::Eval);
                let tr =
                    self.task.eval_metric(self.eng, &self.ps, &eval_train)?;
                let te = self.task.eval_metric(
                    self.eng,
                    &self.ps,
                    self.task.test_items(),
                )?;
                curve.push(epoch + 1, tr, te);
            }
        }
        if self.cfg.method.finetunes() {
            // finetune steps are not part of the Table 3 per-iteration
            // time (the paper reports the main-loop fwd+bwd time)
            self.obs.pause_steps();
            {
                let (task, mut env) = self.split_env();
                let _ft = env.obs.span(Phase::Finetune);
                task.finetune(&mut env, &mut curve, &eval_train)?;
            }
            self.obs.resume_steps();
        }
        let (train_metric, test_metric) = {
            let _eval = self.obs.span(Phase::Eval);
            let tr =
                self.task.eval_metric(self.eng, &self.ps, &eval_train)?;
            let te = self.task.eval_metric(
                self.eng,
                &self.ps,
                self.task.test_items(),
            )?;
            (tr, te)
        };
        let call_counts = self.eng.call_counts();
        let fill_cache = self.task.fill_cache_stats();
        let param_cache = self.eng.param_cache_stats();
        if self.obs.is_enabled() {
            self.obs.gauge("table_bytes", self.table.bytes() as f64);
            self.obs.gauge("table_coverage", self.table.coverage());
            self.obs.gauge(
                "fill_cache_bytes",
                self.task.fill_cache_bytes() as f64,
            );
        }
        let report = self.build_report(
            train_metric,
            test_metric,
            &curve,
            &call_counts,
            fill_cache,
            param_cache,
        );
        self.obs.flush();
        Ok(RunResult {
            train_metric,
            test_metric,
            // steady-state: exclude epoch 0's cold-table steps
            step_ms: self.obs.step_mean_ms_from(self.first_epoch_steps),
            step_p50_ms: self.obs.step_p50_ms(),
            step_p95_ms: self.obs.step_p95_ms(),
            step_max_ms: self.obs.step_max_ms(),
            curve,
            call_counts,
            fill_cache,
            param_cache,
            report,
        })
    }

    /// Sample table coverage + the staleness distribution into the epoch
    /// telemetry (no-op when the recorder is disabled).
    fn record_epoch_telemetry(&self, epoch: usize) {
        if !self.obs.is_enabled() {
            return;
        }
        let mut hist = Histogram::staleness();
        self.table
            .for_each_staleness(self.step, |age| hist.observe(age as f64));
        self.obs.record_epoch(EpochStats {
            epoch,
            coverage: self.table.coverage(),
            mean_staleness: self.table.mean_staleness(self.step),
            hist,
        });
    }

    /// Contention section of the run report: per-lock wait/acquisition
    /// counters from the engine's and the task's timed locks, their
    /// total, and the serial table write-back cost (the one serial
    /// region the commit phase can't parallelize away).
    fn contention_json(&self) -> Json {
        let mut entries = self.eng.lock_stats();
        for (name, s) in self.task.contention() {
            entries.push((format!("task.{name}"), s));
        }
        let total_ms: f64 =
            entries.iter().map(|(_, s)| s.wait_ms()).sum();
        Json::obj(vec![
            (
                "locks",
                Json::Obj(
                    entries
                        .into_iter()
                        .map(|(k, s)| (k, s.to_json()))
                        .collect(),
                ),
            ),
            ("total_wait_ms", Json::num(total_ms)),
            (
                "table_writeback_ms",
                Json::num(self.table_writeback_ns as f64 / 1e6),
            ),
        ])
    }

    /// Assemble the `gst-run-report/v2` document: run context plus every
    /// recorder view plus engine-side accounting (v2 adds the `workers`
    /// and `contention` sections; every v1 field is unchanged). Built
    /// for every run — with the recorder disabled the telemetry
    /// sections are just empty.
    fn build_report(
        &self,
        train_metric: f64,
        test_metric: f64,
        curve: &Curve,
        call_counts: &HashMap<String, usize>,
        fill_cache: CacheStats,
        param_cache: CacheStats,
    ) -> Json {
        let m = &self.eng.manifest;
        let cfg = &self.cfg;
        let calls = Json::Obj(
            call_counts
                .iter()
                .map(|(k, &v)| (k.clone(), Json::num(v as f64)))
                .collect(),
        );
        let per_call_ms = Json::Obj(
            self.eng
                .call_ms()
                .into_iter()
                .map(|(k, v)| (k, Json::num(v)))
                .collect(),
        );
        Json::obj(vec![
            ("schema", Json::str("gst-run-report/v2")),
            ("method", Json::str(cfg.method.name())),
            ("dataset", Json::str(&m.dataset)),
            ("backbone", Json::str(&m.backbone)),
            (
                "config",
                Json::obj(vec![
                    ("epochs", Json::num(cfg.epochs as f64)),
                    (
                        "finetune_epochs",
                        Json::num(cfg.finetune_epochs as f64),
                    ),
                    ("keep_p", Json::num(cfg.keep_p as f64)),
                    ("workers", Json::num(cfg.workers as f64)),
                    (
                        "micro_batches",
                        Json::num(cfg.micro_batches as f64),
                    ),
                    ("seed", Json::num(cfg.seed as f64)),
                    (
                        "fill_cache_mb",
                        Json::num(cfg.fill_cache_mb as f64),
                    ),
                ]),
            ),
            (
                "metrics",
                Json::obj(vec![
                    ("train", Json::num(train_metric)),
                    ("test", Json::num(test_metric)),
                ]),
            ),
            ("curve", curve.to_json()),
            ("steps", self.obs.steps_json(self.first_epoch_steps)),
            ("phases", self.obs.phases_json()),
            ("workers", self.obs.workers_json()),
            ("contention", self.contention_json()),
            ("staleness", self.obs.staleness_json()),
            ("sed", self.obs.sed_json()),
            (
                "caches",
                Json::obj(vec![
                    ("fill", fill_cache.to_json()),
                    ("param_literal", param_cache.to_json()),
                ]),
            ),
            ("calls", calls),
            (
                "engine",
                Json::obj(vec![
                    ("per_call_ms", per_call_ms),
                    (
                        "marshalled_bytes",
                        Json::num(self.eng.marshalled_bytes() as f64),
                    ),
                ]),
            ),
            ("gauges", self.obs.gauges_json()),
            ("counters", self.obs.counters_json()),
        ])
    }

    // -- the shared GST inner loop (Alg. 1/2) -------------------------------

    fn gst_epoch(&mut self, epoch: usize) -> Result<()> {
        let mut order = self.task.train_items().to_vec();
        self.rng.stream(&format!("epoch{epoch}")).shuffle(&mut order);
        let units = self.task.plan_epoch(&order);
        let group = self.cfg.micro_batches.max(1);
        for chunk in units.chunks(group) {
            self.run_group(chunk)?;
        }
        Ok(())
    }

    /// One optimization step: plan → parallel compute → ordered commit.
    fn run_group(&mut self, units: &[Vec<usize>]) -> Result<()> {
        let eng = self.eng;
        let m = &eng.manifest;
        let (b, td) = (m.batch, m.table_dim);
        let method = self.cfg.method;
        let mode = method.sed(self.cfg.keep_p);
        self.obs.set_step(self.step as u64);
        self.obs.step_start();
        let _step_span = self.obs.span(Phase::Step);

        // 1. plan (sequential; table reads see the group-start snapshot)
        let mut plans: Vec<StepPlan<T::StepCtx>> =
            Vec::with_capacity(units.len());
        let mut sed_total = 0u64;
        let mut sed_dropped = 0u64;
        {
            let _sample = self.obs.span(Phase::Sample);
            for (k, unit) in units.iter().enumerate() {
                let step_id = self.step + k as u32;
                let mut rng = self.rng.stream(&format!("step{step_id}"));
                let (ctx, slots) = self.task.begin_step(unit, &mut rng);
                assert_eq!(
                    slots.len(),
                    b,
                    "task must describe all B slots"
                );
                let mut plan = StepPlan {
                    ctx,
                    slots,
                    sampled: vec![0usize; b],
                    eta_fresh: vec![0.0f32; b],
                    stale: vec![0.0f32; b * td],
                    fresh: Vec::new(),
                    step_id,
                };
                for slot in 0..b {
                    let j = plan.slots[slot].num_segments;
                    let s = rng.below(j);
                    plan.sampled[slot] = s;
                    let w = sed_weights(mode, j, s, &mut rng);
                    plan.eta_fresh[slot] = w.eta_fresh;
                    let row = plan.slots[slot].row;
                    for (seg, &eta) in w.eta_stale.iter().enumerate() {
                        if seg == s {
                            continue;
                        }
                        sed_total += 1;
                        if eta == 0.0 {
                            // SED dropped this stale segment (Eq. 1)
                            sed_dropped += 1;
                            continue;
                        }
                        if !method.fresh_stale() {
                            if let Some(h) = self.table.get(row, seg) {
                                for d in 0..td {
                                    plan.stale[slot * td + d] +=
                                        eta * h[d];
                                }
                                continue;
                            }
                            // else: cold entry (first epoch) — recompute
                            // fresh AND write back, Alg. 2's first touch
                        }
                        plan.fresh.push((slot, seg, eta));
                    }
                }
                plans.push(plan);
            }
        }
        self.obs.add("sed_stale_total", sed_total);
        self.obs.add("sed_stale_dropped", sed_dropped);

        // 2. compute (parallel): contiguous shards keep plan order
        let nworkers = self.bufs.len().min(plans.len()).max(1);
        let ranges = threads::chunk_ranges(plans.len(), nworkers);
        let task = &self.task;
        let ps = &self.ps;
        let obs = &self.obs;
        let plans_ref = &plans;
        let ranges_ref = &ranges;
        let worker_out =
            threads::fork_join_with(&mut self.bufs[..nworkers], |w, wb| {
                // tag this worker's spans and time its busy interval —
                // the raw material for the imbalance gauge
                let _scope = obs.worker_scope(w);
                let t0 = Instant::now();
                let out = ranges_ref[w]
                    .clone()
                    .map(|pi| {
                        compute_step(
                            eng,
                            task,
                            ps,
                            &plans_ref[pi],
                            wb,
                            obs,
                        )
                    })
                    .collect::<Result<Vec<StepResult>>>();
                (out, t0.elapsed().as_nanos() as u64)
            });
        // record every worker's busy time before error propagation, so a
        // failing step still leaves consistent telemetry behind
        let busy: Vec<u64> =
            worker_out.iter().map(|(_, ns)| *ns).collect();
        self.obs.record_fork_join(&busy);
        let mut results: Vec<StepResult> = Vec::with_capacity(plans.len());
        for (r, _) in worker_out {
            results.extend(r?);
        }

        // 3. commit (sequential, micro-batch order — deterministic for
        // any worker count). The commit span also covers gradient
        // reduction and the optimizer apply: everything serial after
        // the workers join.
        {
            let _commit = self.obs.span(Phase::TableCommit);
            let t0 = Instant::now();
            for (plan, res) in plans.iter().zip(&results) {
                commit_step(
                    &mut self.table,
                    method.uses_table(),
                    plan,
                    res,
                    td,
                );
            }
            self.table_writeback_ns +=
                t0.elapsed().as_nanos() as u64;
            for res in &results {
                self.accum.add(&res.grads);
            }
            let lr = effective_lr(&self.cfg, eng);
            let avg = self.accum.mean();
            ops::apply(eng, &mut self.ps, avg, lr)?;
        }
        // refresh the cumulative lock-wait total for the heartbeat line
        // and the report (engine caches + any task-owned locks)
        if self.obs.is_enabled() {
            let task_wait: u64 = self
                .task
                .contention()
                .iter()
                .map(|(_, s)| s.wait_ns)
                .sum();
            self.obs.set_lock_wait_ns(eng.lock_wait_ns() + task_wait);
        }
        self.step += plans.len() as u32;
        self.obs.step_stop();
        Ok(())
    }
}

/// Execute one planned micro-batch on a worker's buffers: recompute the
/// planned fresh stale segments through batched `embed_fwd` (staged in
/// the same (nodes, adj, mask) tensors the grad batch overwrites after),
/// then assemble the grad batch and run `grad_step`. Read-only on
/// everything shared.
fn compute_step<T: GstTask>(
    eng: &Engine,
    task: &T,
    ps: &ParamStore,
    plan: &StepPlan<T::StepCtx>,
    bufs: &mut BatchBufs,
    obs: &Recorder,
) -> Result<StepResult> {
    let m = &eng.manifest;
    let (b, td) = (m.batch, m.table_dim);
    // stale aggregate starts from the table-served part of the plan
    bufs.stale.copy_from_slice(&plan.stale);
    // fresh stale embeddings, batched through embed_fwd
    let mut fresh_embs: Vec<Vec<f32>> = Vec::with_capacity(plan.fresh.len());
    for chunk in plan.fresh.chunks(b) {
        {
            let _fill = obs.span(Phase::Fill);
            for bslot in 0..b {
                let (slot, seg, _) =
                    chunk[padded_index(bslot, chunk.len())];
                let (nodes, adj, mask) = bufs.slot(m, bslot);
                task.fill_slot(&plan.ctx, slot, seg, nodes, adj, mask);
            }
        }
        let h = {
            let _fwd = obs.span(Phase::EmbedFwd);
            ops::embed_fwd(eng, ps, &bufs.nodes, &bufs.adj, &bufs.mask)?
        };
        for (i, &(slot, _seg, eta)) in chunk.iter().enumerate() {
            let hv = &h[i * td..(i + 1) * td];
            for d in 0..td {
                bufs.stale[slot * td + d] += eta * hv[d];
            }
            fresh_embs.push(hv.to_vec());
        }
    }
    // grad batch: sampled segments + SED weights + loss buffers
    {
        let _fill = obs.span(Phase::Fill);
        for slot in 0..b {
            bufs.eta[slot] = plan.eta_fresh[slot];
            bufs.invj[slot] = plan.slots[slot].invj;
            let (nodes, adj, mask) = bufs.slot(m, slot);
            task.fill_slot(
                &plan.ctx,
                slot,
                plan.sampled[slot],
                nodes,
                adj,
                mask,
            );
        }
        // reused buffers: tasks only set the pair mask's 1-entries
        bufs.pair.fill(0.0);
        task.fill_loss(&plan.ctx, bufs);
    }
    let out = {
        let _grad = obs.span(Phase::Grad);
        ops::grad_step(eng, ps, bufs)?
    };
    Ok(StepResult { grads: out.grads, h_s: out.h_s, fresh_embs })
}

/// Table write-back for one micro-batch (Alg. 2 line 7): fresh stale
/// recomputations first, then the sampled segments' embeddings, all
/// versioned with the micro-batch's global step index.
fn commit_step<C>(
    table: &mut EmbeddingTable,
    uses_table: bool,
    plan: &StepPlan<C>,
    res: &StepResult,
    td: usize,
) {
    if !uses_table {
        return;
    }
    for (&(slot, seg, _eta), h) in plan.fresh.iter().zip(&res.fresh_embs) {
        table.put(plan.slots[slot].row, seg, h, plan.step_id);
    }
    for (slot, spec) in plan.slots.iter().enumerate() {
        let h = &res.h_s[slot * td..(slot + 1) * td];
        table.put(spec.row, plan.sampled[slot], h, plan.step_id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padded_index_repeats_last_entry() {
        // full chunk: identity
        assert_eq!(
            (0..4).map(|s| padded_index(s, 4)).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
        // short final chunk of 2 in a 4-slot batch: trailing slots
        // repeat the LAST entry (index 1), not entry 0
        assert_eq!(
            (0..4).map(|s| padded_index(s, 2)).collect::<Vec<_>>(),
            vec![0, 1, 1, 1]
        );
        assert_eq!(padded_index(7, 1), 0);
    }

    #[test]
    fn sed_weights_honor_every_mode() {
        let mut rng = Pcg64::new(3, 9);
        let (j, s) = (6usize, 2usize);
        let keep = sed_weights(SedMode::KeepAll, j, s, &mut rng);
        assert_eq!(keep.eta_fresh, 1.0);
        assert_eq!(keep.eta_stale[s], 0.0);
        assert!(keep
            .eta_stale
            .iter()
            .enumerate()
            .all(|(i, &e)| i == s || e == 1.0));
        let drop = sed_weights(SedMode::DropAll, j, s, &mut rng);
        assert_eq!(drop.eta_fresh, j as f32);
        assert!(drop.eta_stale.iter().all(|&e| e == 0.0));
        for p in [0.0f32, 0.3, 1.0] {
            let w = sed_weights(SedMode::Draw(p), j, s, &mut rng);
            assert!((w.eta_fresh - (p + (1.0 - p) * j as f32)).abs() < 1e-6);
            assert_eq!(w.eta_stale[s], 0.0);
            assert!(w.eta_stale.iter().all(|&e| e == 0.0 || e == 1.0));
        }
    }

    fn plan_and_result() -> (StepPlan<()>, StepResult) {
        let slots = vec![
            SlotSpec { row: 0, num_segments: 3, invj: 1.0 / 3.0 },
            SlotSpec { row: 1, num_segments: 2, invj: 0.5 },
        ];
        let plan = StepPlan {
            ctx: (),
            slots,
            sampled: vec![2, 0],
            eta_fresh: vec![1.0, 1.0],
            stale: vec![0.0; 2 * 2],
            fresh: vec![(0, 1, 1.0)],
            step_id: 7,
        };
        let res = StepResult {
            grads: vec![],
            h_s: vec![1.0, 2.0, 3.0, 4.0],
            fresh_embs: vec![vec![9.0, 9.5]],
        };
        (plan, res)
    }

    #[test]
    fn commit_advances_versions_and_values() {
        let mut table = EmbeddingTable::new(&[3, 2], 2);
        let (plan, res) = plan_and_result();
        commit_step(&mut table, true, &plan, &res, 2);
        // fresh stale write-back for slot 0, seg 1
        assert_eq!(table.get(0, 1).unwrap(), &[9.0, 9.5]);
        // sampled-segment write-backs
        assert_eq!(table.get(0, 2).unwrap(), &[1.0, 2.0]);
        assert_eq!(table.get(1, 0).unwrap(), &[3.0, 4.0]);
        // versions advance to the micro-batch's step id
        assert_eq!(table.staleness(0, 2, 7), Some(0));
        assert_eq!(table.staleness(0, 1, 9), Some(2));
        // untouched entries stay unwritten
        assert!(table.get(0, 0).is_none());
        assert!(table.get(1, 1).is_none());
    }

    #[test]
    fn commit_is_a_noop_without_table() {
        let mut table = EmbeddingTable::new(&[3, 2], 2);
        let (plan, res) = plan_and_result();
        commit_step(&mut table, false, &plan, &res, 2);
        assert_eq!(table.coverage(), 0.0);
    }

    #[test]
    fn later_commit_wins_conflicts_deterministically() {
        let mut table = EmbeddingTable::new(&[3, 2], 2);
        let (plan, res) = plan_and_result();
        commit_step(&mut table, true, &plan, &res, 2);
        let (mut plan2, mut res2) = plan_and_result();
        plan2.step_id = 8;
        res2.h_s = vec![5.0, 6.0, 7.0, 8.0];
        commit_step(&mut table, true, &plan2, &res2, 2);
        assert_eq!(table.get(0, 2).unwrap(), &[5.0, 6.0]);
        assert_eq!(table.staleness(0, 2, 8), Some(0));
    }
}
