//! Byte-budgeted cache of fully padded `(nodes, adj, mask)` fill blocks.
//!
//! [`super::PreparedSegments::fill`] already reduces a fill to memcpy +
//! sparse scatter; this cache removes even that for the hottest segments
//! by storing the final padded tensors and serving them with three
//! memcpys. Eviction is clock (second chance): a hit sets the entry's
//! reference bit, the clock hand sweeps and evicts the first entry whose
//! bit is clear.
//!
//! The cache is execution-only: a served block is bit-identical to a
//! fresh fill (pinned by the segment property test), so trained
//! parameters never depend on the budget (`cfg.fill_cache_mb`). Hit/miss
//! counters surface through [`CacheStats`].
//!
//! Interior mutability (one [`TimedMutex`]) keeps `get`/`put` callable
//! from the read-only task fill hooks that run concurrently on worker
//! threads; the mutex doubles as the cache's contention probe
//! ([`FillCache::lock_stats`]).

use crate::metrics::CacheStats;
use crate::util::sync::{LockStats, TimedMutex};
use std::collections::HashMap;

/// Fixed-block-size cache keyed by an opaque `u64` (tasks encode their
/// (row, segment) identity into it).
pub struct FillCache {
    nodes_len: usize,
    adj_len: usize,
    mask_len: usize,
    /// max entries the byte budget holds
    capacity: usize,
    inner: TimedMutex<Inner>,
}

struct Inner {
    map: HashMap<u64, usize>,
    /// key stored in each slot (for eviction-time map removal)
    keys: Vec<u64>,
    /// clock reference bits
    refbit: Vec<bool>,
    hand: usize,
    /// slot-major block storage, grown lazily up to capacity
    data: Vec<f32>,
    hits: u64,
    misses: u64,
}

impl FillCache {
    /// Cache holding at most `budget_mb` MiB of blocks sized for the given
    /// per-tensor lengths. Returns `None` when the budget holds no entry
    /// (`budget_mb = 0` disables caching).
    pub fn new(
        budget_mb: usize,
        nodes_len: usize,
        adj_len: usize,
        mask_len: usize,
    ) -> Option<FillCache> {
        let block_bytes = (nodes_len + adj_len + mask_len) * 4;
        let capacity = (budget_mb << 20) / block_bytes.max(1);
        if capacity == 0 {
            return None;
        }
        Some(FillCache {
            nodes_len,
            adj_len,
            mask_len,
            capacity,
            inner: TimedMutex::new(Inner {
                map: HashMap::new(),
                keys: Vec::new(),
                refbit: Vec::new(),
                hand: 0,
                data: Vec::new(),
                hits: 0,
                misses: 0,
            }),
        })
    }

    fn block(&self) -> usize {
        self.nodes_len + self.adj_len + self.mask_len
    }

    /// Copy `key`'s cached block into the output views; returns `false`
    /// (counting a miss) when the key is absent.
    pub fn get(
        &self,
        key: u64,
        nodes_out: &mut [f32],
        adj_out: &mut [f32],
        mask_out: &mut [f32],
    ) -> bool {
        let mut inner = self.inner.lock();
        let Some(&slot) = inner.map.get(&key) else {
            inner.misses += 1;
            return false;
        };
        inner.hits += 1;
        inner.refbit[slot] = true;
        let base = slot * self.block();
        let (n, a) = (self.nodes_len, self.adj_len);
        nodes_out.copy_from_slice(&inner.data[base..base + n]);
        adj_out.copy_from_slice(&inner.data[base + n..base + n + a]);
        mask_out.copy_from_slice(
            &inner.data[base + n + a..base + self.block()],
        );
        true
    }

    /// Insert (or refresh) `key`'s block, clock-evicting when full.
    pub fn put(
        &self,
        key: u64,
        nodes: &[f32],
        adj: &[f32],
        mask: &[f32],
    ) {
        assert_eq!(nodes.len(), self.nodes_len);
        assert_eq!(adj.len(), self.adj_len);
        assert_eq!(mask.len(), self.mask_len);
        let block = self.block();
        let mut inner = self.inner.lock();
        let slot = if let Some(&s) = inner.map.get(&key) {
            s
        } else if inner.keys.len() < self.capacity {
            let s = inner.keys.len();
            inner.keys.push(key);
            inner.refbit.push(false);
            inner.data.resize((s + 1) * block, 0.0);
            inner.map.insert(key, s);
            s
        } else {
            // clock sweep: clear reference bits until a cold slot appears
            let mut hand = inner.hand;
            while inner.refbit[hand] {
                inner.refbit[hand] = false;
                hand = (hand + 1) % self.capacity;
            }
            inner.hand = (hand + 1) % self.capacity;
            let old = inner.keys[hand];
            inner.map.remove(&old);
            inner.keys[hand] = key;
            inner.map.insert(key, hand);
            hand
        };
        // only hits set the reference bit: a block is "hot" once it has
        // been served, not merely inserted (otherwise a full cache has
        // every bit set and the sweep degenerates to FIFO)
        let base = slot * block;
        let (n, a) = (self.nodes_len, self.adj_len);
        inner.data[base..base + n].copy_from_slice(nodes);
        inner.data[base + n..base + n + a].copy_from_slice(adj);
        inner.data[base + n + a..base + block].copy_from_slice(mask);
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.inner.lock().keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes currently held by resident blocks (telemetry gauge).
    pub fn bytes(&self) -> usize {
        self.len() * self.block() * 4
    }

    /// Cumulative hit/miss counters.
    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock();
        CacheStats { hits: inner.hits, misses: inner.misses }
    }

    /// Contention counters of the cache's internal lock.
    pub fn lock_stats(&self) -> LockStats {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Blocks of (2 + 4 + 2) floats = 32 bytes; 1 MiB holds thousands.
    fn tiny() -> FillCache {
        FillCache::new(1, 2, 4, 2).unwrap()
    }

    #[test]
    fn zero_budget_disables() {
        assert!(FillCache::new(0, 2, 4, 2).is_none());
    }

    #[test]
    fn roundtrip_and_counters() {
        let c = tiny();
        let (mut n, mut a, mut m) = ([9f32; 2], [9f32; 4], [9f32; 2]);
        assert!(!c.get(7, &mut n, &mut a, &mut m));
        c.put(7, &[1.0, 2.0], &[3.0, 4.0, 5.0, 6.0], &[1.0, 0.0]);
        assert!(c.get(7, &mut n, &mut a, &mut m));
        assert_eq!(n, [1.0, 2.0]);
        assert_eq!(a, [3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m, [1.0, 0.0]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        // one resident (2 + 4 + 2)-float block
        assert_eq!(c.bytes(), 32);
    }

    #[test]
    fn put_refreshes_existing_entry() {
        let c = tiny();
        let (mut n, mut a, mut m) = ([0f32; 2], [0f32; 4], [0f32; 2]);
        c.put(1, &[1.0; 2], &[1.0; 4], &[1.0; 2]);
        c.put(1, &[2.0; 2], &[2.0; 4], &[2.0; 2]);
        assert_eq!(c.len(), 1);
        assert!(c.get(1, &mut n, &mut a, &mut m));
        assert_eq!(n, [2.0; 2]);
    }

    #[test]
    fn clock_eviction_keeps_hot_entries() {
        // capacity-sized exactly: blocks of 8 floats (32 B), 1 MiB budget
        // holds plenty, so build a cache whose capacity we then saturate
        let c = FillCache::new(1, 2, 4, 2).unwrap();
        let cap = c.capacity();
        let (mut n, mut a, mut m) = ([0f32; 2], [0f32; 4], [0f32; 2]);
        for k in 0..cap as u64 {
            c.put(k, &[k as f32; 2], &[0.0; 4], &[0.0; 2]);
        }
        assert_eq!(c.len(), cap);
        // touch key 0 (sets its reference bit), then insert a new key:
        // the sweep must skip the hot entry and evict a cold one
        assert!(c.get(0, &mut n, &mut a, &mut m));
        c.put(cap as u64, &[7.0; 2], &[0.0; 4], &[0.0; 2]);
        assert_eq!(c.len(), cap);
        assert!(c.get(0, &mut n, &mut a, &mut m), "hot entry evicted");
        assert!(c.get(cap as u64, &mut n, &mut a, &mut m));
    }

    #[test]
    fn eviction_is_bounded_by_capacity() {
        let c = FillCache::new(1, 2, 4, 2).unwrap();
        let cap = c.capacity();
        for k in 0..(cap as u64) * 3 {
            c.put(k, &[k as f32; 2], &[0.0; 4], &[0.0; 2]);
        }
        assert_eq!(c.len(), cap);
    }
}
