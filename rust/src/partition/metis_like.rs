//! METIS-like multilevel k-way edge-cut partitioner.
//!
//! The classic three phases (Karypis & Kumar '97), implemented from scratch:
//!
//! 1. **Coarsen** — repeated heavy-edge matching collapses matched pairs
//!    into weighted super-nodes until the graph is small;
//! 2. **Initial partition** — greedy BFS region growing over the coarse
//!    graph, balanced by node weight;
//! 3. **Uncoarsen + refine** — project the partition back level by level,
//!    running boundary Kernighan–Lin-style gain moves at each level under
//!    the balance constraint.
//!
//! Guarantees the GST contract (≤ max_size nodes/segment) via the caller's
//! `enforce_max_size` fallback, though refinement respects the bound
//! already in practice.

use super::SegmentSet;
use crate::graph::Csr;
use crate::util::rng::Pcg64;
use std::collections::VecDeque;

/// A weighted graph used during coarsening.
struct WGraph {
    /// adjacency: (neighbor, edge_weight) lists
    adj: Vec<Vec<(u32, u32)>>,
    node_w: Vec<u32>,
}

impl WGraph {
    fn n(&self) -> usize {
        self.adj.len()
    }

    fn from_csr(g: &Csr) -> WGraph {
        let adj = (0..g.num_nodes())
            .map(|v| g.neighbors(v).iter().map(|&w| (w, 1u32)).collect())
            .collect();
        WGraph { adj, node_w: vec![1; g.num_nodes()] }
    }
}

pub fn partition(g: &Csr, max_size: usize, rng: &mut Pcg64) -> SegmentSet {
    let n = g.num_nodes();
    if n <= max_size {
        return SegmentSet {
            segments: vec![(0..n as u32).collect()],
            edges: None,
        };
    }
    let k = n.div_ceil((max_size as f64 * 0.85) as usize);
    // Phase 1: coarsen, remembering the node maps
    let mut levels: Vec<WGraph> = vec![WGraph::from_csr(g)];
    let mut maps: Vec<Vec<u32>> = Vec::new(); // fine node -> coarse node
    while levels.last().unwrap().n() > (4 * k).max(64) {
        let (coarse, map) = coarsen(levels.last().unwrap(), rng);
        if coarse.n() as f64 > levels.last().unwrap().n() as f64 * 0.95 {
            break; // matching saturated
        }
        maps.push(map);
        levels.push(coarse);
    }
    // Phase 2: initial partition of the coarsest graph
    let coarsest = levels.last().unwrap();
    let mut part = grow_initial(coarsest, k, rng);
    // Phase 3: uncoarsen + refine
    for lvl in (0..maps.len()).rev() {
        // project to the finer level
        let fine = &levels[lvl];
        let map = &maps[lvl];
        let mut fine_part = vec![0u32; fine.n()];
        for v in 0..fine.n() {
            fine_part[v] = part[map[v] as usize];
        }
        part = fine_part;
        refine(fine, &mut part, k, max_size, 4);
    }
    // final refinement at the original resolution already done (lvl 0)
    let mut segments = vec![Vec::new(); k];
    for (v, &p) in part.iter().enumerate() {
        segments[p as usize].push(v as u32);
    }
    segments.retain(|s| !s.is_empty());
    SegmentSet { segments, edges: None }
}

/// Heavy-edge matching: visit nodes in random order, match each unmatched
/// node with its unmatched neighbor of maximum edge weight.
fn coarsen(g: &WGraph, rng: &mut Pcg64) -> (WGraph, Vec<u32>) {
    let n = g.n();
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut mate = vec![u32::MAX; n];
    for &u in &order {
        let u = u as usize;
        if mate[u] != u32::MAX {
            continue;
        }
        let mut best: Option<(u32, u32)> = None; // (weight, neighbor)
        for &(v, w) in &g.adj[u] {
            if mate[v as usize] == u32::MAX && v as usize != u {
                if best.map(|(bw, _)| w > bw).unwrap_or(true) {
                    best = Some((w, v));
                }
            }
        }
        match best {
            Some((_, v)) => {
                mate[u] = v;
                mate[v as usize] = u as u32;
            }
            None => mate[u] = u as u32, // matched with itself
        }
    }
    // assign coarse ids
    let mut map = vec![u32::MAX; n];
    let mut next = 0u32;
    for v in 0..n {
        if map[v] != u32::MAX {
            continue;
        }
        map[v] = next;
        let m = mate[v] as usize;
        if m != v {
            map[m] = next;
        }
        next += 1;
    }
    // build the coarse weighted graph (aggregate parallel edges)
    let cn = next as usize;
    let mut node_w = vec![0u32; cn];
    for v in 0..n {
        node_w[map[v] as usize] += g.node_w[v];
    }
    let mut agg: Vec<std::collections::HashMap<u32, u32>> =
        vec![std::collections::HashMap::new(); cn];
    for u in 0..n {
        let cu = map[u];
        for &(v, w) in &g.adj[u] {
            let cv = map[v as usize];
            if cu != cv {
                *agg[cu as usize].entry(cv).or_insert(0) += w;
            }
        }
    }
    let adj = agg
        .into_iter()
        .map(|m| {
            let mut v: Vec<(u32, u32)> = m.into_iter().collect();
            v.sort_unstable();
            v
        })
        .collect();
    (WGraph { adj, node_w }, map)
}

/// Greedy BFS region growing on the coarse graph, weight-balanced.
fn grow_initial(g: &WGraph, k: usize, rng: &mut Pcg64) -> Vec<u32> {
    let n = g.n();
    let total_w: u64 = g.node_w.iter().map(|&w| w as u64).sum();
    let target = (total_w as f64 / k as f64).ceil() as u64;
    let mut part = vec![u32::MAX; n];
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut cur = 0u32;
    let mut cur_w = 0u64;
    let mut queue = VecDeque::new();
    let mut oi = 0usize;
    while oi < n {
        // find next unassigned seed
        while oi < n && part[order[oi] as usize] != u32::MAX {
            oi += 1;
        }
        if oi >= n {
            break;
        }
        queue.clear();
        queue.push_back(order[oi]);
        part[order[oi] as usize] = cur;
        while let Some(u) = queue.pop_front() {
            cur_w += g.node_w[u as usize] as u64;
            if cur_w >= target && (cur as usize) < k - 1 {
                cur += 1;
                cur_w = 0;
                // nodes still in the queue move to the new part
                for &q in &queue {
                    part[q as usize] = cur;
                }
            }
            for &(v, _) in &g.adj[u as usize] {
                if part[v as usize] == u32::MAX {
                    part[v as usize] = cur;
                    queue.push_back(v);
                }
            }
        }
    }
    part
}

/// Boundary gain refinement: move nodes to the neighboring part with the
/// largest cut-weight gain, while keeping every part under the size bound.
fn refine(g: &WGraph, part: &mut [u32], k: usize, max_size: usize, passes: usize) {
    let n = g.n();
    let mut part_w = vec![0u64; k];
    for v in 0..n {
        part_w[part[v] as usize] += g.node_w[v] as u64;
    }
    let cap = max_size as u64;
    for _ in 0..passes {
        let mut moved = 0usize;
        for v in 0..n {
            let pv = part[v];
            // connection weight to each adjacent part
            let mut conn: Vec<(u32, i64)> = Vec::new();
            for &(u, w) in &g.adj[v] {
                let pu = part[u as usize];
                match conn.iter_mut().find(|(p, _)| *p == pu) {
                    Some((_, cw)) => *cw += w as i64,
                    None => conn.push((pu, w as i64)),
                }
            }
            let own = conn
                .iter()
                .find(|(p, _)| *p == pv)
                .map(|&(_, w)| w)
                .unwrap_or(0);
            let mut best: Option<(i64, u32)> = None;
            for &(p, w) in &conn {
                if p == pv {
                    continue;
                }
                let gain = w - own;
                if gain > 0
                    && part_w[p as usize] + g.node_w[v] as u64 <= cap
                    && best.map(|(bg, _)| gain > bg).unwrap_or(true)
                {
                    best = Some((gain, p));
                }
            }
            if let Some((_, p)) = best {
                part_w[pv as usize] -= g.node_w[v] as u64;
                part_w[p as usize] += g.node_w[v] as u64;
                part[v] = p;
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    /// Two dense clusters joined by one edge: the canonical partition test.
    fn barbell(half: usize) -> Csr {
        let mut b = GraphBuilder::new(half * 2, 0);
        for c in 0..2 {
            let off = c * half;
            for i in 0..half {
                for j in i + 1..half {
                    if (i + j) % 3 != 0 {
                        b.add_edge(off + i, off + j);
                    }
                }
            }
        }
        b.add_edge(half - 1, half);
        b.build()
    }

    #[test]
    fn splits_barbell_at_the_bridge() {
        let g = barbell(40);
        let mut rng = Pcg64::new(0, 0);
        let set = partition(&g, 48, &mut rng);
        set.validate(&g, 48).unwrap();
        assert_eq!(set.segments.len(), 2);
        // cut should be exactly the bridge
        assert_eq!(set.cut_cost(&g), 1);
    }

    #[test]
    fn small_graph_single_segment() {
        let g = barbell(10);
        let mut rng = Pcg64::new(0, 0);
        let set = partition(&g, 100, &mut rng);
        assert_eq!(set.segments.len(), 1);
        assert_eq!(set.segments[0].len(), 20);
    }

    #[test]
    fn coarsening_preserves_total_weight() {
        let g = barbell(30);
        let wg = WGraph::from_csr(&g);
        let mut rng = Pcg64::new(2, 2);
        let (coarse, map) = coarsen(&wg, &mut rng);
        let total: u32 = coarse.node_w.iter().sum();
        assert_eq!(total as usize, g.num_nodes());
        assert!(coarse.n() < g.num_nodes());
        assert!(map.iter().all(|&m| (m as usize) < coarse.n()));
    }

    #[test]
    fn balanced_on_grid() {
        let mut b = GraphBuilder::new(400, 0);
        for y in 0..20 {
            for x in 0..20 {
                let v = y * 20 + x;
                if x + 1 < 20 {
                    b.add_edge(v, v + 1);
                }
                if y + 1 < 20 {
                    b.add_edge(v, v + 20);
                }
            }
        }
        let g = b.build();
        let mut rng = Pcg64::new(3, 3);
        let set = partition(&g, 128, &mut rng);
        set.validate(&g, 128).unwrap();
        assert!(set.segments.len() >= 4);
        // grid of 400 into ≤128 chunks: every part ≥ 40 (no slivers)
        assert!(set.segments.iter().all(|s| s.len() >= 40));
    }
}
