"""L1 Pallas kernels for the GST compute hot-spots.

All kernels run with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); they still lower through the real BlockSpec schedules, which
is what the section-Perf analytic TPU model is derived from.
"""

from .attention import linear_attention
from .matmul import ACT_NONE, ACT_PRELU, ACT_RELU, linear, matmul_bias_act
from .spmm import adj_matmul

__all__ = [
    "ACT_NONE",
    "ACT_PRELU",
    "ACT_RELU",
    "adj_matmul",
    "linear",
    "linear_attention",
    "matmul_bias_act",
]
