//! The paper's core claim, demonstrated: GST's peak memory is constant in
//! graph size, while full-graph training scales linearly into OOM.
//!
//!     cargo run --release --example memory_footprint

use gst::memory::MemoryModel;

fn main() {
    let m = MemoryModel::malnet_paper("sage");
    println!("activation-memory model at PAPER scale (V100 16 GB, hidden 300)\n");
    println!(
        "{:>12} {:>12} {:>14} {:>14}",
        "nodes", "edges", "full-graph", "GST (seg 5k)"
    );
    let gst = m.gst_peak_bytes(16, 1, 5_000, 20_000);
    for scale in [1usize, 4, 16, 64, 256] {
        let nodes = 1_410 * scale; // MalNet-Tiny avg, scaled up
        let edges = 2_860 * scale;
        let full = m.full_graph_peak(&vec![(nodes, edges); 16]);
        println!(
            "{:>12} {:>12} {:>11.2} GiB {:>11.2} GiB{}",
            nodes,
            edges,
            full as f64 / (1u64 << 30) as f64,
            gst as f64 / (1u64 << 30) as f64,
            if m.full_graph_ooms(&vec![(nodes, edges); 16]) {
                "   <- full-graph OOM"
            } else {
                ""
            }
        );
    }
    println!(
        "\nGST peak depends only on (batch x sampled-segment size): the\n\
         column never moves. This is Figure 1(b)'s argument in numbers."
    );
}
