//! Observability layer: phase-scoped spans, a counter/gauge registry,
//! staleness telemetry, a JSONL trace sink and machine-readable run
//! reports (DESIGN.md §8).
//!
//! Everything here is **execution-only**: the [`Recorder`] never feeds
//! back into training, and when no sink is configured (`record` off, no
//! trace file, no heartbeat) every telemetry method is a cheap no-op, so
//! trained parameters are bit-identical with observability on or off
//! (pinned by `tests/gst_core.rs`).
//!
//! One exception is deliberate: the per-step wall-clock timer is always
//! on, because `RunResult.step_ms` is a core output of every run
//! (Table 3), not an opt-in diagnostic.

pub mod hist;
mod report;
mod sink;

pub use hist::Histogram;

use std::cell::Cell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::metrics::StepTimer;
use crate::util::json::Json;
use anyhow::Result;
use sink::TraceSink;

/// Sink configuration carried inside `TrainConfig` (all off by default,
/// which makes the recorder a no-op).
#[derive(Clone, Debug, Default)]
pub struct ObsConfig {
    /// Collect phase/staleness/cache telemetry for the run report even
    /// without a trace file (`--report-json` sets this).
    pub record: bool,
    /// JSONL trace-event stream path (`--trace-out`).
    pub trace_out: Option<String>,
    /// Print a heartbeat line to stderr every N optimizer steps
    /// (`--log-every`; 0 = off).
    pub log_every: usize,
}

/// The fixed phase taxonomy spans are attributed to. `Step` is the outer
/// span wrapping one optimizer step; the rest are its leaves (plus the
/// out-of-step `Eval` and `Finetune` phases).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Step,
    Sample,
    Fill,
    EmbedFwd,
    Grad,
    TableCommit,
    Eval,
    Finetune,
}

impl Phase {
    pub const ALL: [Phase; 8] = [
        Phase::Step,
        Phase::Sample,
        Phase::Fill,
        Phase::EmbedFwd,
        Phase::Grad,
        Phase::TableCommit,
        Phase::Eval,
        Phase::Finetune,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Step => "step",
            Phase::Sample => "sample",
            Phase::Fill => "fill",
            Phase::EmbedFwd => "embed_fwd",
            Phase::Grad => "grad",
            Phase::TableCommit => "table_commit",
            Phase::Eval => "eval",
            Phase::Finetune => "finetune",
        }
    }

    fn idx(self) -> usize {
        self as usize
    }
}

/// Per-epoch staleness snapshot sampled from the embedding table after
/// each training epoch.
#[derive(Clone, Debug)]
pub struct EpochStats {
    /// 1-based epoch index (matches `Curve.epochs`).
    pub epoch: usize,
    /// Fraction of table rows ever written.
    pub coverage: f64,
    /// Mean staleness over written rows, in optimizer steps.
    pub mean_staleness: f64,
    /// Staleness distribution over written rows.
    pub hist: Histogram,
}

thread_local! {
    /// Span nesting depth on this thread (worker threads start at 0).
    static DEPTH: Cell<u32> = Cell::new(0);
}

/// Run-wide telemetry hub. All methods take `&self` (interior
/// mutability) and the type is `Sync`, so one recorder is shared by the
/// sequential plan/commit path and the parallel compute workers alike.
pub struct Recorder {
    enabled: bool,
    t0: Instant,
    log_every: u64,
    cur_step: AtomicU64,
    phase_ns: [AtomicU64; 8],
    phase_calls: [AtomicU64; 8],
    steps: Mutex<StepTimer>,
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    epochs: Mutex<Vec<EpochStats>>,
    sink: Option<TraceSink>,
}

impl Recorder {
    /// Recorder with every optional sink off (step timing still runs).
    pub fn disabled() -> Recorder {
        Recorder::build(false, 0, None)
    }

    /// Build from the run's [`ObsConfig`]; opening the trace file is the
    /// only fallible part.
    pub fn new(cfg: &ObsConfig) -> Result<Recorder> {
        let sink = match &cfg.trace_out {
            Some(path) => Some(TraceSink::create(path)?),
            None => None,
        };
        let enabled = cfg.record || sink.is_some() || cfg.log_every > 0;
        Ok(Recorder::build(enabled, cfg.log_every as u64, sink))
    }

    fn build(
        enabled: bool,
        log_every: u64,
        sink: Option<TraceSink>,
    ) -> Recorder {
        Recorder {
            enabled,
            t0: Instant::now(),
            log_every,
            cur_step: AtomicU64::new(0),
            phase_ns: std::array::from_fn(|_| AtomicU64::new(0)),
            phase_calls: std::array::from_fn(|_| AtomicU64::new(0)),
            steps: Mutex::new(StepTimer::default()),
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            epochs: Mutex::new(Vec::new()),
            sink,
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    // -- step wall-clock (always on: RunResult.step_ms is a core output)

    pub fn step_start(&self) {
        self.steps.lock().unwrap().start();
    }

    /// Close the current step sample; prints the heartbeat line when
    /// `--log-every` is set.
    pub fn step_stop(&self) {
        let (recorded, count, last_ms, mean_ms) = {
            let mut t = self.steps.lock().unwrap();
            let before = t.count();
            t.stop();
            (t.count() > before, t.count(), t.last_ms(), t.mean_ms())
        };
        if !recorded {
            return; // paused section (finetune): nothing was sampled
        }
        if self.log_every > 0 && count as u64 % self.log_every == 0 {
            eprintln!(
                "[obs] step={count} last_ms={last_ms:.2} \
                 mean_ms={mean_ms:.2}"
            );
        }
    }

    /// Enter an untimed section (the +F finetuning phase, which the
    /// paper's per-iteration numbers exclude).
    pub fn pause_steps(&self) {
        self.steps.lock().unwrap().pause();
    }

    /// Leave the untimed section.
    pub fn resume_steps(&self) {
        self.steps.lock().unwrap().resume();
    }

    pub fn step_count(&self) -> usize {
        self.steps.lock().unwrap().count()
    }

    pub fn step_mean_ms_from(&self, skip: usize) -> f64 {
        self.steps.lock().unwrap().mean_ms_from(skip)
    }

    pub fn step_p50_ms(&self) -> f64 {
        self.steps.lock().unwrap().p50_ms()
    }

    pub fn step_p95_ms(&self) -> f64 {
        self.steps.lock().unwrap().p95_ms()
    }

    pub fn step_max_ms(&self) -> f64 {
        self.steps.lock().unwrap().max_ms()
    }

    // -- spans, counters, gauges, points (no-ops when disabled) --

    /// Tag subsequent trace events with the current optimizer-step index.
    pub fn set_step(&self, step: u64) {
        if self.enabled {
            self.cur_step.store(step, Ordering::Relaxed);
        }
    }

    /// RAII phase timer; returns an inert guard when disabled. Guards
    /// nest: each carries the depth at which it was opened.
    pub fn span(&self, phase: Phase) -> Span<'_> {
        if !self.enabled {
            return Span { inner: None };
        }
        let depth = DEPTH.with(|d| {
            let v = d.get();
            d.set(v + 1);
            v
        });
        Span {
            inner: Some(SpanInner {
                rec: self,
                phase,
                start: Instant::now(),
                depth,
            }),
        }
    }

    /// Add to a named counter.
    pub fn add(&self, name: &str, n: u64) {
        if self.enabled && n > 0 {
            *self
                .counters
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_insert(0) += n;
        }
    }

    /// Read a counter back (0 when absent or disabled).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Set a named gauge to its latest value.
    pub fn gauge(&self, name: &str, value: f64) {
        if self.enabled {
            self.gauges.lock().unwrap().insert(name.to_string(), value);
        }
    }

    /// Record one epoch's staleness snapshot (also emitted as a trace
    /// point when a sink is attached).
    pub fn record_epoch(&self, stats: EpochStats) {
        if !self.enabled {
            return;
        }
        self.point(
            "epoch_staleness",
            Json::obj(vec![
                ("epoch", Json::num(stats.epoch as f64)),
                ("coverage", Json::num(stats.coverage)),
                ("mean", Json::num(stats.mean_staleness)),
            ]),
        );
        self.epochs.lock().unwrap().push(stats);
    }

    /// Emit a named point event to the trace sink, if any.
    pub fn point(&self, name: &str, data: Json) {
        let Some(sink) = &self.sink else { return };
        sink.write(&Json::obj(vec![
            ("ev", Json::str("point")),
            ("name", Json::str(name)),
            ("t_us", Json::num(self.t_us())),
            ("data", data),
        ]));
    }

    /// Flush the trace sink (end of run).
    pub fn flush(&self) {
        if let Some(sink) = &self.sink {
            sink.flush();
        }
    }

    fn t_us(&self) -> f64 {
        self.t0.elapsed().as_micros() as f64
    }
}

/// RAII guard from [`Recorder::span`]: measures wall-clock from creation
/// to drop and attributes it to the span's phase.
pub struct Span<'a> {
    inner: Option<SpanInner<'a>>,
}

struct SpanInner<'a> {
    rec: &'a Recorder,
    phase: Phase,
    start: Instant,
    depth: u32,
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        let Some(s) = self.inner.take() else { return };
        DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
        let ns = s.start.elapsed().as_nanos() as u64;
        let i = s.phase.idx();
        s.rec.phase_ns[i].fetch_add(ns, Ordering::Relaxed);
        s.rec.phase_calls[i].fetch_add(1, Ordering::Relaxed);
        if let Some(sink) = &s.rec.sink {
            let step = s.rec.cur_step.load(Ordering::Relaxed);
            let t_us =
                s.start.duration_since(s.rec.t0).as_micros() as f64;
            sink.write(&Json::obj(vec![
                ("ev", Json::str("span")),
                ("phase", Json::str(s.phase.name())),
                ("step", Json::num(step as f64)),
                ("t_us", Json::num(t_us)),
                ("dur_us", Json::num(ns as f64 / 1e3)),
                ("depth", Json::num(s.depth as f64)),
            ]));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_is_send_and_sync() {
        fn assert_sync<T: Send + Sync>() {}
        assert_sync::<Recorder>();
    }

    #[test]
    fn disabled_recorder_is_inert() {
        let r = Recorder::disabled();
        assert!(!r.is_enabled());
        {
            let _outer = r.span(Phase::Step);
            let _inner = r.span(Phase::Fill);
        }
        r.add("x", 3);
        r.gauge("g", 1.0);
        assert_eq!(r.counter("x"), 0);
        let j = r.phases_json();
        for p in Phase::ALL {
            assert_eq!(j.at(p.name()).at("calls").as_f64(), Some(0.0));
        }
        // ...but the step timer still runs (step_ms is a core output)
        r.step_start();
        r.step_stop();
        assert_eq!(r.step_count(), 1);
    }

    #[test]
    fn spans_nest_and_accumulate() {
        let r = Recorder::new(&ObsConfig {
            record: true,
            ..ObsConfig::default()
        })
        .unwrap();
        {
            let _step = r.span(Phase::Step);
            {
                let _fill = r.span(Phase::Fill);
                std::hint::black_box((0..10_000).sum::<u64>());
            }
            {
                let _grad = r.span(Phase::Grad);
            }
        }
        let j = r.phases_json();
        assert_eq!(j.at("step").at("calls").as_f64(), Some(1.0));
        assert_eq!(j.at("fill").at("calls").as_f64(), Some(1.0));
        assert_eq!(j.at("grad").at("calls").as_f64(), Some(1.0));
        let step_ms = j.at("step").at("total_ms").as_f64().unwrap();
        let fill_ms = j.at("fill").at("total_ms").as_f64().unwrap();
        let grad_ms = j.at("grad").at("total_ms").as_f64().unwrap();
        // the outer span covers both inner ones
        assert!(step_ms >= fill_ms + grad_ms);
    }

    #[test]
    fn counters_and_gauges_accumulate_when_enabled() {
        let r = Recorder::new(&ObsConfig {
            record: true,
            ..ObsConfig::default()
        })
        .unwrap();
        r.add("sed_stale_total", 2);
        r.add("sed_stale_total", 3);
        r.add("zero", 0);
        r.gauge("mem", 1.5);
        r.gauge("mem", 2.5);
        assert_eq!(r.counter("sed_stale_total"), 5);
        assert_eq!(r.counter("zero"), 0);
        let g = r.gauges_json();
        assert_eq!(g.at("mem").as_f64(), Some(2.5));
    }
}
