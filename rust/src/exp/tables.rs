//! Drivers for the paper's tables (1, 2, 3, 4, 6).

use super::common::*;
use crate::datasets::malnet::MalnetSplit;
use crate::graph::GraphStats;
use crate::partition::Algorithm;
use crate::train::{Method, TrainConfig};
use crate::util::json::Json;
use anyhow::Result;
use std::collections::BTreeMap;

fn base_cfg(env: &Env, method: Method, seed: u64) -> TrainConfig {
    TrainConfig {
        method,
        epochs: env.profile.epochs,
        finetune_epochs: env.profile.finetune_epochs,
        eval_every: env.profile.epochs.max(1),
        seed,
        ..TrainConfig::default()
    }
}

/// Table 1: test accuracy, MalNet-{Tiny,Large} × {GCN,SAGE,GPS} × methods.
pub fn table1(env: &Env) -> Result<()> {
    let mut cells: BTreeMap<String, Cell> = BTreeMap::new();
    let splits = [MalnetSplit::Tiny, MalnetSplit::Large];
    let backbones = ["gcn", "sage", "gps"];
    for &split in &splits {
        for backbone in backbones {
            let variant = format!("malnet_{backbone}_n128");
            let eng = env.engine(&variant)?;
            for seed in 0..env.profile.seeds as u64 {
                let data = env.malnet(split, seed);
                for method in table1_methods() {
                    let key =
                        format!("{}/{backbone}/{}", split.name(), method.name());
                    let cell = cells.entry(key.clone()).or_default();
                    if cell.note.is_some() {
                        continue;
                    }
                    match run_malnet(
                        env,
                        &eng,
                        &data,
                        base_cfg(env, method, seed),
                        &key,
                    ) {
                        Ok(res) => cell.push(res.test_metric),
                        Err(e) if e.to_string().contains("OOM") => {
                            *cell = Cell::oom();
                        }
                        Err(e) => return Err(e),
                    }
                }
            }
        }
    }
    // render in the paper's layout: rows = methods, cols = split×backbone
    let mut cols = Vec::new();
    for &split in &splits {
        for backbone in backbones {
            cols.push(format!("{}/{backbone}", split.name()));
        }
    }
    let rows: Vec<(String, Vec<String>)> = table1_methods()
        .iter()
        .map(|m| {
            let cells_row: Vec<String> = cols
                .iter()
                .map(|c| {
                    cells
                        .get(&format!("{c}/{}", m.name()))
                        .map(|cell| cell.render(100.0))
                        .unwrap_or_else(|| "-".into())
                })
                .collect();
            (m.name().to_string(), cells_row)
        })
        .collect();
    print_table("Table 1: test accuracy (%) on MalNet", &cols, &rows);
    let path = env.save("table1", cells_to_json(&cells))?;
    println!("saved {path}");
    Ok(())
}

/// Table 2: train/test OPA on TpuGraphs.
pub fn table2(env: &Env) -> Result<()> {
    let eng = env.engine("tpu_sage_n128")?;
    let mut cells: BTreeMap<String, Cell> = BTreeMap::new();
    for seed in 0..env.profile.seeds as u64 {
        let data = env.tpu(seed);
        for method in table2_methods() {
            let mut cfg = base_cfg(env, method, seed);
            cfg.epochs = env.profile.tpu_epochs;
            let (tr_key, te_key) = (
                format!("{}/train", method.name()),
                format!("{}/test", method.name()),
            );
            if cells.get(&tr_key).map(|c| c.note.is_some()).unwrap_or(false) {
                continue;
            }
            let label = format!("{}/seed{seed}", method.name());
            match run_tpu(env, &eng, &data, cfg, &label) {
                Ok(res) => {
                    cells.entry(tr_key).or_default().push(res.train_metric);
                    cells.entry(te_key).or_default().push(res.test_metric);
                }
                Err(e) if e.to_string().contains("OOM") => {
                    cells.insert(tr_key, Cell::oom());
                    cells.insert(te_key, Cell::oom());
                }
                Err(e) => return Err(e),
            }
        }
    }
    let cols = vec!["Train OPA".to_string(), "Test OPA".to_string()];
    let rows: Vec<(String, Vec<String>)> = table2_methods()
        .iter()
        .map(|m| {
            (
                m.name().to_string(),
                vec![
                    cells
                        .get(&format!("{}/train", m.name()))
                        .map(|c| c.render(100.0))
                        .unwrap_or("-".into()),
                    cells
                        .get(&format!("{}/test", m.name()))
                        .map(|c| c.render(100.0))
                        .unwrap_or("-".into()),
                ],
            )
        })
        .collect();
    print_table("Table 2: OPA (%) on TpuGraphs", &cols, &rows);
    let path = env.save("table2", cells_to_json(&cells))?;
    println!("saved {path}");
    Ok(())
}

/// Table 3: average training time per iteration (ms) on MalNet-Large.
pub fn table3(env: &Env) -> Result<()> {
    let backbones = ["gcn", "sage", "gps"];
    let methods = table2_methods(); // Full(OOM), GST, GST-One, +E, +EFD
    let mut cells: BTreeMap<String, Cell> = BTreeMap::new();
    let data = env.malnet(MalnetSplit::Large, 0);
    for backbone in backbones {
        let eng = env.engine(&format!("malnet_{backbone}_n128"))?;
        for &method in &methods {
            let mut cfg = base_cfg(env, method, 0);
            cfg.epochs = 8.min(env.profile.epochs.max(2));
            cfg.finetune_epochs = 0;
            cfg.eval_every = 99;
            let key = format!("{backbone}/{}", method.name());
            match run_malnet(env, &eng, &data, cfg, &key) {
                Ok(res) => cells.entry(key).or_default().push(res.step_ms),
                Err(e) if e.to_string().contains("OOM") => {
                    cells.insert(key, Cell::oom());
                }
                Err(e) => return Err(e),
            }
        }
    }
    let cols: Vec<String> = backbones.iter().map(|s| s.to_string()).collect();
    let rows: Vec<(String, Vec<String>)> = methods
        .iter()
        .map(|m| {
            (
                m.name().to_string(),
                cols.iter()
                    .map(|b| {
                        cells
                            .get(&format!("{b}/{}", m.name()))
                            .map(|c| c.render(1.0))
                            .unwrap_or("-".into())
                    })
                    .collect(),
            )
        })
        .collect();
    print_table(
        "Table 3: train time per iteration (ms), MalNet-Large",
        &cols,
        &rows,
    );
    let path = env.save("table3", cells_to_json(&cells))?;
    println!("saved {path}");
    Ok(())
}

/// Table 4: dataset statistics.
pub fn table4(env: &Env) -> Result<()> {
    println!("\n=== Table 4: dataset statistics ===");
    println!("{}", GraphStats::header());
    let tiny = env.malnet(MalnetSplit::Tiny, 0);
    println!("{}", GraphStats::over(&tiny.graphs).row("malnet-tiny"));
    let large = env.malnet(MalnetSplit::Large, 0);
    println!("{}", GraphStats::over(&large.graphs).row("malnet-large"));
    let tpu = env.tpu(0);
    let tpu_graphs: Vec<_> =
        tpu.graphs.iter().map(|g| g.csr.clone()).collect();
    println!("{}", GraphStats::over(&tpu_graphs).row("tpugraphs"));
    let total_pairs: usize =
        tpu.graphs.iter().map(|g| g.configs.len()).sum();
    println!("tpugraphs: {} graphs x configs = {total_pairs} samples",
             tpu.graphs.len());
    let stats = |gs: &[crate::graph::Csr]| {
        let s = GraphStats::over(gs);
        Json::obj(vec![
            ("avg_nodes", Json::num(s.avg_nodes)),
            ("max_nodes", Json::num(s.max_nodes as f64)),
            ("avg_edges", Json::num(s.avg_edges)),
            ("max_edges", Json::num(s.max_edges as f64)),
        ])
    };
    let payload = Json::obj(vec![
        ("malnet_tiny", stats(&tiny.graphs)),
        ("malnet_large", stats(&large.graphs)),
        ("tpugraphs", stats(&tpu_graphs)),
    ]);
    let path = env.save("table4", payload)?;
    println!("saved {path}");
    Ok(())
}

/// Table 6: partition-algorithm ablation (GST+EFD, SAGE).
pub fn table6(env: &Env) -> Result<()> {
    let eng = env.engine("malnet_sage_n128")?;
    let algs = [
        ("Edge-Cut Random", Algorithm::EdgeCutRandom),
        ("Edge-Cut Louvain", Algorithm::Louvain),
        ("Edge-Cut METIS-like", Algorithm::MetisLike),
        ("Edge-Cut BFS", Algorithm::EdgeCutBfs),
        ("Vertex-Cut Random", Algorithm::VertexCutRandom),
        ("Vertex-Cut DBH", Algorithm::VertexCutDbh),
        ("Vertex-Cut NE", Algorithm::VertexCutNe),
    ];
    let splits = [MalnetSplit::Tiny, MalnetSplit::Large];
    let mut cells: BTreeMap<String, Cell> = BTreeMap::new();
    for &split in &splits {
        for seed in 0..env.profile.seeds as u64 {
            let data = env.malnet(split, seed);
            for (name, alg) in algs {
                let mut cfg = base_cfg(env, Method::GstEFD, seed);
                cfg.partition = alg;
                let key = format!("{name}/{}", split.name());
                let res = run_malnet(env, &eng, &data, cfg, &key)?;
                cells.entry(key).or_default().push(res.test_metric);
            }
        }
    }
    let cols: Vec<String> =
        splits.iter().map(|s| s.name().to_string()).collect();
    let rows: Vec<(String, Vec<String>)> = algs
        .iter()
        .map(|(name, _)| {
            (
                name.to_string(),
                cols.iter()
                    .map(|c| {
                        cells
                            .get(&format!("{name}/{c}"))
                            .map(|cell| cell.render(100.0))
                            .unwrap_or("-".into())
                    })
                    .collect(),
            )
        })
        .collect();
    print_table(
        "Table 6: partition algorithms, GST+EFD + SAGE, test accuracy (%)",
        &cols,
        &rows,
    );
    let path = env.save("table6", cells_to_json(&cells))?;
    println!("saved {path}");
    Ok(())
}
