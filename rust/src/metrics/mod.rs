//! Evaluation metrics and curve tracking: top-1 accuracy (MalNet), ordered
//! pair accuracy (TpuGraphs, paper §5.3), loss curves and wall-clock
//! timers for the Table 3 runtime analysis.

use std::time::Instant;

/// Top-1 accuracy from logits.
pub fn accuracy(logits: &[Vec<f32>], labels: &[u8]) -> f64 {
    assert_eq!(logits.len(), labels.len());
    if logits.is_empty() {
        return 0.0;
    }
    let correct = logits
        .iter()
        .zip(labels)
        .filter(|(lg, &y)| argmax(lg) == y as usize)
        .count();
    correct as f64 / logits.len() as f64
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Softmax cross-entropy (for loss curves; mirrors the L2 definition).
pub fn cross_entropy(logits: &[Vec<f32>], labels: &[u8]) -> f64 {
    assert_eq!(logits.len(), labels.len());
    if logits.is_empty() {
        return 0.0;
    }
    let mut total = 0f64;
    for (lg, &y) in logits.iter().zip(labels) {
        let mx = lg.iter().cloned().fold(f32::MIN, f32::max) as f64;
        let logz = mx
            + lg.iter()
                .map(|&x| ((x as f64) - mx).exp())
                .sum::<f64>()
                .ln();
        total += logz - lg[y as usize] as f64;
    }
    total / logits.len() as f64
}

/// Ordered Pair Accuracy over one graph's configs (paper §5.3):
/// `OPA = Σ_ij 1[ŷ_i > ŷ_j]·1[y_i > y_j] / Σ_ij 1[y_i > y_j]`.
pub fn opa(yhat: &[f32], y: &[f32]) -> f64 {
    assert_eq!(yhat.len(), y.len());
    let mut num = 0usize;
    let mut den = 0usize;
    for i in 0..y.len() {
        for j in 0..y.len() {
            if y[i] > y[j] {
                den += 1;
                if yhat[i] > yhat[j] {
                    num += 1;
                }
            }
        }
    }
    if den == 0 {
        1.0
    } else {
        num as f64 / den as f64
    }
}

/// Mean OPA over graphs (each graph contributes equally, as in the paper).
pub fn mean_opa(per_graph: &[(Vec<f32>, Vec<f32>)]) -> f64 {
    if per_graph.is_empty() {
        return 0.0;
    }
    per_graph
        .iter()
        .map(|(yh, y)| opa(yh, y))
        .sum::<f64>()
        / per_graph.len() as f64
}

/// Hit/miss counters for the execution-only caches (the segment
/// fill-block cache and the engine's parameter-literal cache — DESIGN.md
/// §7). Cheap to copy; snapshots are taken at end of run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    pub fn total(&self) -> u64 {
        self.hits + self.misses
    }

    /// Hit fraction in [0, 1] (0.0 before any lookup).
    pub fn hit_rate(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.hits as f64 / self.total() as f64
        }
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("hits", Json::num(self.hits as f64)),
            ("misses", Json::num(self.misses as f64)),
            ("hit_rate", Json::num(self.hit_rate())),
        ])
    }
}

/// Accumulates per-epoch points for the Figure 2/5/6 curves.
#[derive(Clone, Debug, Default)]
pub struct Curve {
    pub epochs: Vec<usize>,
    pub train: Vec<f64>,
    pub test: Vec<f64>,
}

impl Curve {
    pub fn push(&mut self, epoch: usize, train: f64, test: f64) {
        self.epochs.push(epoch);
        self.train.push(train);
        self.test.push(test);
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("epochs", Json::arr(self.epochs.iter().map(|&e| Json::num(e as f64)))),
            ("train", Json::arr(self.train.iter().map(|&x| Json::num(x)))),
            ("test", Json::arr(self.test.iter().map(|&x| Json::num(x)))),
        ])
    }
}

/// Wall-clock timer bucket: per-phase cumulative times + per-iteration
/// samples (Table 3 reports mean ms/iteration).
///
/// Untimed sections are explicit: [`StepTimer::pause`] suppresses
/// sampling (any in-flight sample is discarded and `start`/`stop` become
/// no-ops) until [`StepTimer::resume`]. The trainer pauses around the +F
/// finetuning phase, which the paper's per-iteration numbers exclude.
#[derive(Clone, Debug, Default)]
pub struct StepTimer {
    samples_ms: Vec<f64>,
    started: Option<Instant>,
    paused: bool,
}

impl StepTimer {
    pub fn start(&mut self) {
        if !self.paused {
            self.started = Some(Instant::now());
        }
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.samples_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        }
    }

    /// Enter an untimed section: drop any in-flight sample and ignore
    /// `start`/`stop` until [`StepTimer::resume`].
    pub fn pause(&mut self) {
        self.paused = true;
        self.started = None;
    }

    /// Leave the untimed section.
    pub fn resume(&mut self) {
        self.paused = false;
    }

    pub fn is_paused(&self) -> bool {
        self.paused
    }

    pub fn mean_ms(&self) -> f64 {
        crate::util::stats::mean(&self.samples_ms)
    }

    /// Mean excluding the first `skip` samples — the steady-state number
    /// Table 3 reports (the first epoch pays one-off cold-table costs).
    pub fn mean_ms_from(&self, skip: usize) -> f64 {
        if self.samples_ms.len() > skip {
            crate::util::stats::mean(&self.samples_ms[skip..])
        } else {
            self.mean_ms()
        }
    }

    pub fn p50_ms(&self) -> f64 {
        crate::util::stats::percentile(&self.samples_ms, 50.0)
    }

    /// 95th-percentile sample — the tail that a Table 3 mean hides.
    pub fn p95_ms(&self) -> f64 {
        crate::util::stats::percentile(&self.samples_ms, 95.0)
    }

    pub fn max_ms(&self) -> f64 {
        crate::util::stats::max(&self.samples_ms)
    }

    /// Most recent sample (0.0 before the first), for heartbeat lines.
    pub fn last_ms(&self) -> f64 {
        self.samples_ms.last().copied().unwrap_or(0.0)
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_argmax() {
        let logits = vec![
            vec![0.1, 0.9, 0.0],
            vec![0.8, 0.1, 0.1],
            vec![0.2, 0.3, 0.5],
        ];
        let labels = vec![1u8, 0, 0];
        assert!((accuracy(&logits, &labels) - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn ce_matches_manual() {
        let logits = vec![vec![2.0, 0.0]];
        let want = (1f64 + (-2f64).exp()).ln();
        assert!((cross_entropy(&logits, &[0]) - want).abs() < 1e-6);
    }

    #[test]
    fn opa_perfect_and_inverted() {
        let y = vec![1.0, 2.0, 3.0];
        assert_eq!(opa(&[1.0, 2.0, 3.0], &y), 1.0);
        assert_eq!(opa(&[3.0, 2.0, 1.0], &y), 0.0);
    }

    #[test]
    fn opa_ties_in_predictions_score_zero() {
        let y = vec![1.0, 2.0];
        assert_eq!(opa(&[5.0, 5.0], &y), 0.0);
    }

    #[test]
    fn opa_no_ordered_pairs() {
        assert_eq!(opa(&[1.0, 2.0], &[3.0, 3.0]), 1.0);
    }

    #[test]
    fn mean_opa_averages_per_graph() {
        let g1 = (vec![1.0, 2.0], vec![1.0, 2.0]); // 1.0
        let g2 = (vec![2.0, 1.0], vec![1.0, 2.0]); // 0.0
        assert!((mean_opa(&[g1, g2]) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn timer_collects_samples() {
        let mut t = StepTimer::default();
        for _ in 0..3 {
            t.start();
            std::hint::black_box((0..10_000).sum::<u64>());
            t.stop();
        }
        assert_eq!(t.count(), 3);
        assert!(t.mean_ms() >= 0.0);
    }

    #[test]
    fn paused_sections_record_nothing() {
        let mut t = StepTimer::default();
        t.start();
        t.stop();
        assert_eq!(t.count(), 1);
        // pausing mid-sample drops the in-flight sample
        t.start();
        t.pause();
        assert!(t.is_paused());
        t.stop();
        // start/stop inside the paused section are no-ops
        t.start();
        t.stop();
        assert_eq!(t.count(), 1);
        t.resume();
        assert!(!t.is_paused());
        t.start();
        t.stop();
        assert_eq!(t.count(), 2);
    }

    #[test]
    fn timer_tail_stats() {
        let t = StepTimer::default();
        assert_eq!(t.p95_ms(), 0.0);
        assert_eq!(t.max_ms(), 0.0);
        assert_eq!(t.last_ms(), 0.0);
        let mut t = StepTimer::default();
        for _ in 0..10 {
            t.start();
            t.stop();
        }
        assert!(t.max_ms() >= t.p95_ms());
        assert!(t.p95_ms() >= t.p50_ms());
        assert!(t.last_ms() >= 0.0);
    }

    #[test]
    fn cache_stats_rates() {
        let s = CacheStats::default();
        assert_eq!(s.total(), 0);
        assert_eq!(s.hit_rate(), 0.0);
        let s = CacheStats { hits: 3, misses: 1 };
        assert_eq!(s.total(), 4);
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        let j = s.to_json();
        assert_eq!(j.at("hits").as_f64(), Some(3.0));
        assert!((j.at("hit_rate").as_f64().unwrap() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn curve_json_shape() {
        let mut c = Curve::default();
        c.push(1, 0.5, 0.4);
        let j = c.to_json();
        assert_eq!(j.at("epochs").as_arr().unwrap().len(), 1);
    }
}
