//! Declarative flag parser (clap is unavailable offline — DESIGN.md §3).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, repeated
//! flags, positional arguments, and auto-generated `--help`.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    takes_value: bool,
}

/// Builder-style argument parser.
pub struct Cli {
    program: String,
    about: String,
    flags: Vec<FlagSpec>,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, Vec<String>>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Cli { program: program.into(), about: about.into(), flags: vec![] }
    }

    /// A flag that takes a value, with an optional default.
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.into(),
            help: help.into(),
            default: default.map(String::from),
            takes_value: true,
        });
        self
    }

    /// A boolean switch.
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            takes_value: false,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nFLAGS:\n", self.program, self.about);
        for f in &self.flags {
            let d = f
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{:<18} {}{}\n", f.name, f.help, d));
        }
        s
    }

    /// Parse a raw argv slice (without the program name).
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        for f in &self.flags {
            if let Some(d) = &f.default {
                out.values.insert(f.name.clone(), vec![d.clone()]);
            }
        }
        let mut i = 0;
        let mut defaulted: BTreeMap<&str, bool> =
            self.flags.iter().map(|f| (f.name.as_str(), true)).collect();
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(name) = a.strip_prefix("--") {
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n\n{}", self.usage()))?;
                let value = if !spec.takes_value {
                    "true".to_string()
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| format!("--{name} needs a value"))?
                };
                let entry = out.values.entry(spec.name.clone()).or_default();
                if defaulted[spec.name.as_str()] {
                    entry.clear();
                    defaulted.insert(&spec.name, false);
                }
                entry.push(value);
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.values
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.get(name) == Some("true")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("epochs", Some("10"), "number of epochs")
            .opt("method", None, "training method")
            .switch("verbose", "log more")
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cli().parse(&argv(&[])).unwrap();
        assert_eq!(a.get_usize("epochs").unwrap(), 10);
        let a = cli().parse(&argv(&["--epochs", "5"])).unwrap();
        assert_eq!(a.get_usize("epochs").unwrap(), 5);
        let a = cli().parse(&argv(&["--epochs=7"])).unwrap();
        assert_eq!(a.get_usize("epochs").unwrap(), 7);
    }

    #[test]
    fn switches_and_positionals() {
        let a = cli().parse(&argv(&["run", "--verbose", "x"])).unwrap();
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional, vec!["run", "x"]);
        assert!(!cli().parse(&argv(&[])).unwrap().get_bool("verbose"));
    }

    #[test]
    fn repeated_flags_collect() {
        let a = cli()
            .parse(&argv(&["--method", "gst", "--method", "gst+efd"]))
            .unwrap();
        assert_eq!(a.get_all("method"), vec!["gst", "gst+efd"]);
        assert_eq!(a.get("method"), Some("gst+efd"));
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(cli().parse(&argv(&["--nope"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = cli().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("--epochs"));
    }
}
