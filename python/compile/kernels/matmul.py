"""L1 Pallas kernel: tiled matmul with fused bias + activation.

Every dense layer in every backbone routes through this kernel, so it is the
MXU hot-spot of the whole stack. The TPU mapping (see DESIGN.md
section Hardware-Adaptation):

  * grid over (row-block i, col-block j); each step pulls an (bm, K) tile of
    ``x`` and a (K, bn) tile of ``w`` HBM->VMEM via BlockSpec, multiplies on
    the MXU with f32 accumulation, then fuses bias-add + activation in the
    VPU before the single store back to HBM.
  * K is kept whole inside a block: all K used by this model family are
    <= 256, so an (128, 256) f32 tile is 128 KiB — far under the ~16 MiB
    VMEM budget, and avoids a reduction-carry loop.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO (still exercising the exact
BlockSpec schedule). Real-TPU performance is estimated analytically in
DESIGN.md / EXPERIMENTS.md section Perf via `vmem_bytes` / `mxu_utilization`
below.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Activation tags understood by the fused epilogue.
ACT_NONE = "none"
ACT_RELU = "relu"
ACT_PRELU = "prelu"

# Default MXU-aligned tile sizes (128x128 systolic array).
BLOCK_M = 128
BLOCK_N = 128


def _mm_kernel(x_ref, w_ref, b_ref, alpha_ref, o_ref, *, act: str):
    """One (bm, bn) output tile: MXU matmul + fused bias/activation."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if act == ACT_RELU:
        acc = jnp.maximum(acc, 0.0)
    elif act == ACT_PRELU:
        a = alpha_ref[0]
        acc = jnp.where(acc >= 0.0, acc, a * acc)
    o_ref[...] = acc.astype(o_ref.dtype)


def _block_sizes(m: int, n: int):
    bm = BLOCK_M if m % BLOCK_M == 0 else m
    bn = BLOCK_N if n % BLOCK_N == 0 else n
    return bm, bn


def _matmul_pallas(x, w, b, alpha, act: str):
    """Raw pallas forward: ``act(x @ w + b)`` for one activation tag."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert b.shape == (n,), (b.shape, n)
    assert alpha.shape == (1,), alpha.shape
    bm, bn = _block_sizes(m, n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_mm_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b, alpha)


def _mm_plain(a, bmat):
    """Pallas matmul with no epilogue — the building block of the backward
    pass (dx = g @ w^T and dw = x^T @ g reuse the same MXU schedule)."""
    k = a.shape[-1]
    zero_b = jnp.zeros((bmat.shape[-1],), jnp.float32)
    zero_a = jnp.zeros((1,), jnp.float32)
    return _matmul_pallas(a, bmat, zero_b, zero_a, ACT_NONE)


# Pallas interpret mode has no reverse-mode rule for pallas_call, so the
# kernel carries an explicit custom_vjp whose backward pass is *also* built
# from pallas matmuls (training is the hot path in GST, not inference).
@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def matmul_bias_act(x, w, b, alpha, act: str = ACT_NONE):
    """``act(x @ w + b)`` with a PReLU slope ``alpha`` (shape (1,)).

    x: (M, K) f32; w: (K, N) f32; b: (N,) f32; alpha: (1,) f32.
    M and N must be divisible by the chosen block sizes (all dims in this
    model family are powers of two or small enough to become one block).
    """
    return _matmul_pallas(x, w, b, alpha, act)


def _mm_fwd(x, w, b, alpha, act):
    # Residuals: inputs + pre-activation z. Keeping z is the classic
    # activation-memory trade GST bounds by segment size.
    z = _matmul_pallas(x, w, b, alpha, ACT_NONE)
    if act == ACT_RELU:
        y = jnp.maximum(z, 0.0)
    elif act == ACT_PRELU:
        y = jnp.where(z >= 0.0, z, alpha[0] * z)
    else:
        y = z
    return y, (x, w, alpha, z)


def _mm_bwd(act, res, g):
    x, w, alpha, z = res
    if act == ACT_RELU:
        gz = g * (z > 0.0)
        galpha = jnp.zeros((1,), jnp.float32)
    elif act == ACT_PRELU:
        gz = g * jnp.where(z >= 0.0, 1.0, alpha[0])
        galpha = jnp.sum(g * jnp.where(z < 0.0, z, 0.0))[None]
    else:
        gz = g
        galpha = jnp.zeros((1,), jnp.float32)
    dx = _mm_plain(gz, w.T)  # (M, K)
    dw = _mm_plain(x.T, gz)  # (K, N)
    db = jnp.sum(gz, axis=0)
    return dx, dw, db, galpha


matmul_bias_act.defvjp(_mm_fwd, _mm_bwd)


def linear(x, w, b, alpha=None, act: str = ACT_NONE):
    """Rank-polymorphic wrapper: flattens leading dims into M."""
    if alpha is None:
        alpha = jnp.zeros((1,), jnp.float32)
    lead = x.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    out = matmul_bias_act(x.reshape(m, x.shape[-1]), w, b, alpha, act)
    return out.reshape(*lead, w.shape[-1])


# ---------------------------------------------------------------------------
# Analytic TPU performance model (interpret=True wallclock is NOT a TPU
# proxy; these estimates drive the section-Perf iteration).
# ---------------------------------------------------------------------------

def vmem_bytes(m: int, k: int, n: int) -> int:
    """VMEM bytes resident for one grid step of the schedule above."""
    bm, bn = _block_sizes(m, n)
    return 4 * (bm * k + k * bn + bn + 1 + bm * bn)


def mxu_utilization(m: int, k: int, n: int) -> float:
    """Useful MACs / systolic-array MACs for one tile, assuming the 128x128
    MXU processes ceil(bm/128)*ceil(bn/128)*ceil(k/128) passes."""
    bm, bn = _block_sizes(m, n)
    ceil = lambda a, q: -(-a // q)
    passes = ceil(bm, 128) * ceil(bn, 128) * ceil(k, 128)
    return (bm * bn * k) / (passes * 128 * 128 * 128)
