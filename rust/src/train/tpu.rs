//! TpuGraphs task: per-graph config *ranking* via pairwise hinge loss
//! and ordered pair accuracy (Table 2, Fig 5).
//!
//! Paper §5.3 specifics honored here:
//! * one 𝒢^(i) = (graph, configuration) — configs are featurized into the
//!   node features, so the table is keyed by (graph, config, segment);
//! * the head is inside F and F' is a parameter-free sum, so the +F
//!   finetuning stage is omitted (GST+EFD = GST+ED here — the core's
//!   default no-op `finetune`) — and the table stores scalars
//!   (table_dim = 1);
//! * PairwiseHinge within a batch: we batch B configs *of the same graph*
//!   (ranking across graphs is meaningless), with the ordering mask built
//!   from measured runtimes;
//! * sum pooling — `invj` stays 1.0 (no 1/J).
//!
//! The inner loop itself (sampling, SED, table, averaging, timing) is
//! [`GstCore`](super::core::GstCore)'s.

use super::core::{GstCore, GstTask, SlotSpec};
use super::ops::{self, BatchBufs};
use super::{Method, TrainConfig};
use crate::datasets::TpuDataset;
use crate::metrics::{self, CacheStats};
use crate::runtime::{Engine, ParamStore};
use crate::segment::{FillHandle, PreparedSegments, SegmentedGraph};
use crate::util::rng::Pcg64;
use crate::util::sync::LockStats;
use anyhow::{bail, Result};

/// The TpuGraphs trainer is the shared core driving a [`TpuTask`].
pub type TpuTrainer<'a> = GstCore<'a, TpuTask<'a>>;

impl<'a> GstCore<'a, TpuTask<'a>> {
    pub fn new(
        eng: &'a Engine,
        data: &'a TpuDataset,
        cfg: TrainConfig,
    ) -> Result<TpuTrainer<'a>> {
        let task = TpuTask::new(eng, data, &cfg)?;
        GstCore::with_task(eng, task, cfg)
    }

    /// Mean OPA over `graphs`: predicted runtime of each config = Σ_j r_j
    /// with fresh embeddings (F' = sum, paper §5.3).
    pub fn evaluate(&self, graphs: &[usize]) -> Result<f64> {
        self.task.eval(self.engine(), &self.ps, graphs)
    }
}

pub struct TpuTask<'a> {
    data: &'a TpuDataset,
    /// one partition per graph, shared by all of its configs
    segs: Vec<SegmentedGraph>,
    /// per-graph precomputed fills; config features arrive per call via
    /// the override gather path
    prepared: Vec<PreparedSegments>,
    /// handle onto the (possibly process-shared) padded fill-block
    /// cache, keyed by (graph, config, segment) since configs change the
    /// node features
    fill: FillHandle,
    /// table rows are (graph, config) pairs: row = pair_off[g] + c
    pair_off: Vec<usize>,
    batch: usize,
}

/// Per-step state: the graph being ranked, the B sampled configs and
/// their materialized feature tensors (built once in the plan phase,
/// read by every fill — no per-slot cloning).
pub struct TpuStepCtx {
    g: usize,
    configs: Vec<usize>,
    feats: Vec<Vec<f32>>,
}

impl<'a> TpuTask<'a> {
    fn new(
        eng: &Engine,
        data: &'a TpuDataset,
        cfg: &TrainConfig,
    ) -> Result<TpuTask<'a>> {
        let m = &eng.manifest;
        assert_eq!(m.dataset, "tpu");
        if cfg.method == Method::FullGraph {
            bail!(
                "OOM: Full Graph Training on TpuGraphs exceeds the device \
                 budget (paper Table 2) — no full_step artifact is built"
            );
        }
        let mut rng = Pcg64::new(cfg.seed, 0x7965).stream("partition");
        let max = m.max_nodes;
        let segs: Vec<SegmentedGraph> = data
            .graphs
            .iter()
            .map(|g| {
                let set = cfg.partition.partition(&g.csr, max, &mut rng);
                SegmentedGraph::new(&g.csr, &set)
            })
            .collect();
        let mut pair_off = Vec::with_capacity(data.graphs.len());
        let mut rows = 0usize;
        for g in &data.graphs {
            pair_off.push(rows);
            rows += g.configs.len();
        }
        let prepared = data
            .graphs
            .iter()
            .zip(&segs)
            .map(|(g, sg)| {
                PreparedSegments::new(&g.csr, sg, m.adj_norm, max, m.feat)
            })
            .collect();
        let fill = FillHandle::new(
            cfg.fill_cache_mb,
            cfg.shared_fill_cache,
            max * m.feat,
            max * max,
            max,
        );
        Ok(TpuTask {
            data,
            segs,
            prepared,
            fill,
            pair_off,
            batch: m.batch,
        })
    }

    fn pair_row(&self, g: usize, c: usize) -> usize {
        self.pair_off[g] + c
    }

    /// The single fill path every site routes through: serve the
    /// (graph, config, segment) block from the fill cache when present,
    /// else run the prepared fill with `feats` (the config's featurized
    /// node tensor) and populate the cache. Bit-identical to a direct
    /// `fill_padded` either way.
    fn fill_one(
        &self,
        g: usize,
        c: usize,
        seg: usize,
        feats: &[f32],
        nodes: &mut [f32],
        adj: &mut [f32],
        mask: &mut [f32],
    ) {
        // (graph, config) rows and segments stay far below 2^24 here
        let key = ((self.pair_row(g, c) as u64) << 24) | seg as u64;
        if self.fill.get(key, nodes, adj, mask) {
            return;
        }
        self.prepared[g].fill(seg, Some(feats), nodes, adj, mask);
        self.fill.put(key, nodes, adj, mask);
    }

    /// Fresh per-segment runtime contributions for (graph, config, seg)
    /// triples — the eval path. Config feature tensors are materialized
    /// once per (graph, config) and borrowed from the cache for every
    /// slot that reuses them.
    fn embed_eval(
        &self,
        eng: &Engine,
        ps: &ParamStore,
        items: &[(usize, usize, usize)],
    ) -> Result<Vec<Vec<f32>>> {
        let m = &eng.manifest;
        let (b, n, f, td) = (m.batch, m.max_nodes, m.feat, m.table_dim);
        let mut out = Vec::with_capacity(items.len());
        let mut nodes = vec![0f32; b * n * f];
        let mut adj = vec![0f32; b * n * n];
        let mut mask = vec![0f32; b * n];
        // cache config feature materializations within this call
        let mut cache: std::collections::HashMap<(usize, usize), Vec<f32>> =
            std::collections::HashMap::new();
        for chunk in items.chunks(b) {
            for slot in 0..b {
                let (g, c, s) =
                    chunk[super::core::padded_index(slot, chunk.len())];
                let feats = cache.entry((g, c)).or_insert_with(|| {
                    self.data.graphs[g].features_for_config(c)
                });
                self.fill_one(
                    g, c, s, feats.as_slice(),
                    &mut nodes[slot * n * f..(slot + 1) * n * f],
                    &mut adj[slot * n * n..(slot + 1) * n * n],
                    &mut mask[slot * n..(slot + 1) * n],
                );
            }
            let h = ops::embed_fwd(eng, ps, &nodes, &adj, &mask)?;
            for slot in 0..chunk.len() {
                out.push(h[slot * td..(slot + 1) * td].to_vec());
            }
        }
        Ok(out)
    }

    /// Mean OPA over `graphs`.
    pub fn eval(
        &self,
        eng: &Engine,
        ps: &ParamStore,
        graphs: &[usize],
    ) -> Result<f64> {
        let mut per_graph = Vec::with_capacity(graphs.len());
        for &g in graphs {
            let graph = &self.data.graphs[g];
            let j = self.segs[g].num_segments();
            let mut items = Vec::new();
            for c in 0..graph.configs.len() {
                for s in 0..j {
                    items.push((g, c, s));
                }
            }
            let embs = self.embed_eval(eng, ps, &items)?;
            let mut yhat = vec![0f32; graph.configs.len()];
            for ((_, c, _), h) in items.iter().zip(&embs) {
                yhat[*c] += h[0];
            }
            per_graph.push((yhat, graph.runtimes.clone()));
        }
        Ok(metrics::mean_opa(&per_graph))
    }
}

impl GstTask for TpuTask<'_> {
    type StepCtx = TpuStepCtx;

    fn dataset(&self) -> &'static str {
        "tpu"
    }

    fn seed_tag(&self) -> u64 {
        0x7965
    }

    fn warmup_fns(&self, _method: Method) -> Vec<&'static str> {
        vec!["grad_step", "apply_step", "embed_fwd"]
    }

    fn table_rows(&self) -> Vec<usize> {
        let mut counts = Vec::new();
        for (gi, g) in self.data.graphs.iter().enumerate() {
            for _ in 0..g.configs.len() {
                counts.push(self.segs[gi].num_segments());
            }
        }
        counts
    }

    fn train_items(&self) -> &[usize] {
        &self.data.train
    }

    /// One ranking micro-batch per training graph.
    fn plan_epoch(&self, order: &[usize]) -> Vec<Vec<usize>> {
        order.iter().map(|&g| vec![g]).collect()
    }

    fn begin_step(
        &mut self,
        unit: &[usize],
        rng: &mut Pcg64,
        slots: &mut Vec<SlotSpec>,
    ) -> TpuStepCtx {
        assert_eq!(unit.len(), 1, "tpu units are single graphs");
        let g = unit[0];
        let graph = &self.data.graphs[g];
        let ncfg = graph.configs.len();
        let b = self.batch;
        // B configs, distinct when possible
        let configs: Vec<usize> = if ncfg >= b {
            rng.sample_indices(ncfg, b)
        } else {
            (0..b).map(|i| i % ncfg).collect()
        };
        let j = self.segs[g].num_segments();
        let feats: Vec<Vec<f32>> = configs
            .iter()
            .map(|&c| graph.features_for_config(c))
            .collect();
        slots.extend(configs.iter().map(|&c| SlotSpec {
            row: self.pair_row(g, c),
            num_segments: j,
            // sum pooling: no 1/J (paper §5.3)
            invj: 1.0,
        }));
        TpuStepCtx { g, configs, feats }
    }

    fn bind_fill_generation(&mut self, gen: u64) {
        self.fill.bind_generation(gen);
    }

    /// Pairwise ordering mask within the batch (same graph); the core
    /// hands `bufs.pair` over zeroed, so only the 1-entries are written.
    fn fill_loss(&self, ctx: &TpuStepCtx, bufs: &mut BatchBufs) {
        let b = self.batch;
        let rt = &self.data.graphs[ctx.g].runtimes;
        for slot in 0..b {
            for other in 0..b {
                if rt[ctx.configs[slot]] > rt[ctx.configs[other]] {
                    bufs.pair[slot * b + other] = 1.0;
                }
            }
        }
    }

    fn fill_slot(
        &self,
        ctx: &TpuStepCtx,
        slot: usize,
        seg: usize,
        nodes: &mut [f32],
        adj: &mut [f32],
        mask: &mut [f32],
    ) {
        self.fill_one(
            ctx.g,
            ctx.configs[slot],
            seg,
            ctx.feats[slot].as_slice(),
            nodes,
            adj,
            mask,
        );
    }

    fn eval_metric(
        &self,
        eng: &Engine,
        ps: &ParamStore,
        items: &[usize],
    ) -> Result<f64> {
        self.eval(eng, ps, items)
    }

    fn eval_train_subset(&self) -> Vec<usize> {
        self.data.train.iter().take(8).copied().collect()
    }

    fn test_items(&self) -> &[usize] {
        &self.data.test
    }

    fn total_segments(&self) -> usize {
        self.segs.iter().map(|s| s.num_segments()).sum()
    }

    fn fill_cache_stats(&self) -> CacheStats {
        self.fill.stats()
    }

    fn prepared_bytes(&self) -> usize {
        self.prepared.iter().map(|p| p.bytes()).sum()
    }

    fn fill_cache_bytes(&self) -> usize {
        self.fill.bytes()
    }

    fn contention(&self) -> Vec<(String, LockStats)> {
        self.fill.contention()
    }
}
