//! Integration: load the real AOT artifacts through PJRT and check numerics
//! against the L2 semantics (python/tests/test_aot.py validated jit==eager;
//! here we validate text-load==jit by exercising known identities).
//!
//! Requires `make artifacts` to have produced artifacts/malnet_sage_n128.

use gst::runtime::engine::HostTensor;
use gst::runtime::{Engine, ParamStore};

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/malnet_sage_n128");
    std::path::Path::new(dir).is_dir().then(|| dir.to_string())
}

fn param_inputs(ps: &ParamStore) -> Vec<HostTensor> {
    ps.values.iter().map(|v| HostTensor::F32(v.clone())).collect()
}

#[test]
fn embed_fwd_masked_mean_properties() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let eng = Engine::open(&dir).unwrap();
    let m = &eng.manifest;
    let (b, n, f, h) = (m.batch, m.max_nodes, m.feat, m.hidden);
    let ps = ParamStore::load(&dir, m).unwrap();

    // identical inputs in every batch slot must give identical embeddings
    let mut nodes = vec![0f32; b * n * f];
    let adj = vec![0f32; b * n * n];
    let mut mask = vec![0f32; b * n];
    for slot in 0..b {
        for v in 0..4 {
            mask[slot * n + v] = 1.0;
            for d in 0..f {
                nodes[(slot * n + v) * f + d] = (v * f + d) as f32 * 0.01;
            }
        }
    }
    let mut inputs = param_inputs(&ps);
    inputs.push(nodes.clone().into());
    inputs.push(adj.clone().into());
    inputs.push(mask.clone().into());
    let out = eng.call("embed_fwd", &inputs).unwrap();
    assert_eq!(out.len(), 1);
    let emb = out[0].f32s();
    assert_eq!(emb.len(), b * h);
    for slot in 1..b {
        for d in 0..h {
            assert!(
                (emb[d] - emb[slot * h + d]).abs() < 1e-5,
                "slot {slot} dim {d}: {} vs {}",
                emb[d],
                emb[slot * h + d]
            );
        }
    }
    assert!(emb.iter().all(|x| x.is_finite()));

    // changing features of MASKED nodes must not change the embedding
    let mut nodes2 = nodes.clone();
    for slot in 0..b {
        for v in 4..n {
            for d in 0..f {
                nodes2[(slot * n + v) * f + d] = 7.5;
            }
        }
    }
    let mut inputs2 = param_inputs(&ps);
    inputs2.push(nodes2.into());
    inputs2.push(adj.into());
    inputs2.push(mask.into());
    let out2 = eng.call("embed_fwd", &inputs2).unwrap();
    let emb2 = out2[0].f32s();
    for i in 0..b * h {
        assert!((emb[i] - emb2[i]).abs() < 1e-4, "padding leaked at {i}");
    }
}

#[test]
fn grad_step_then_apply_reduces_loss() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let eng = Engine::open(&dir).unwrap();
    let m = &eng.manifest;
    let (b, n, f, h) = (m.batch, m.max_nodes, m.feat, m.hidden);
    let mut ps = ParamStore::load(&dir, m).unwrap();
    let np = m.params.len();

    // fixed batch: random-ish but deterministic features, J=1 per graph
    let mut nodes = vec![0f32; b * n * f];
    for (i, x) in nodes.iter_mut().enumerate() {
        *x = ((i * 2654435761) % 1000) as f32 / 1000.0 - 0.5;
    }
    let mut adj = vec![0f32; b * n * n];
    for g in 0..b {
        for v in 0..15 {
            adj[g * n * n + v * n + v + 1] = 1.0;
            adj[g * n * n + (v + 1) * n + v] = 1.0;
        }
    }
    let mut mask = vec![0f32; b * n];
    for g in 0..b {
        for v in 0..16 {
            mask[g * n + v] = 1.0;
        }
    }
    let stale = vec![0f32; b * h];
    let eta = vec![1f32; b];
    let invj = vec![1f32; b];
    let labels: Vec<i32> = (0..b as i32).map(|i| i % 5).collect();

    let run_step = |ps: &ParamStore| -> (f32, Vec<HostTensor>) {
        let mut inputs = param_inputs(ps);
        inputs.push(nodes.clone().into());
        inputs.push(adj.clone().into());
        inputs.push(mask.clone().into());
        inputs.push(stale.clone().into());
        inputs.push(eta.clone().into());
        inputs.push(invj.clone().into());
        inputs.push(labels.clone().into());
        let out = eng.call("grad_step", &inputs).unwrap();
        let loss = out[0].f32s()[0];
        (loss, out)
    };

    let (loss0, out) = run_step(&ps);
    assert!(loss0.is_finite() && loss0 > 0.0);
    // h_s output has table_dim
    assert_eq!(out.last().unwrap().len(), b * m.table_dim);

    // 30 SGD steps on the same batch must reduce the loss substantially
    let mut loss_prev = loss0;
    for step in 1..=30 {
        let (_, out) = run_step(&ps);
        let grads: Vec<HostTensor> = out[1..1 + np].to_vec();
        let mut inputs: Vec<HostTensor> = param_inputs(&ps);
        inputs.extend(ps.m.iter().map(|x| HostTensor::F32(x.clone())));
        inputs.extend(ps.v.iter().map(|x| HostTensor::F32(x.clone())));
        inputs.extend(grads);
        inputs.push(HostTensor::F32(vec![step as f32]));
        inputs.push(HostTensor::F32(vec![eng.manifest.lr]));
        let new = eng.call("apply_step", &inputs).unwrap();
        for i in 0..np {
            ps.values[i] = new[i].f32s().to_vec();
            ps.m[i] = new[np + i].f32s().to_vec();
            ps.v[i] = new[2 * np + i].f32s().to_vec();
        }
        loss_prev = run_step(&ps).0;
    }
    // 30 Adam steps at the manifest lr (1e-3) cut this fixed-batch loss
    // by ~1/3; demand a robust 20% drop (a broken grad/apply path shows
    // flat or rising loss)
    assert!(
        loss_prev < loss0 * 0.8,
        "loss did not drop: {loss0} -> {loss_prev}"
    );
}

#[test]
fn predict_uses_head_params_only() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let eng = Engine::open(&dir).unwrap();
    let m = &eng.manifest;
    let ps = ParamStore::load(&dir, m).unwrap();
    let head = m.head_indices();
    let (b, h, c) = (m.batch, m.hidden, m.classes);
    let hg: Vec<f32> = (0..b * h).map(|i| (i % 13) as f32 * 0.05).collect();
    let mut inputs: Vec<HostTensor> =
        head.iter().map(|&i| HostTensor::F32(ps.values[i].clone())).collect();
    inputs.push(hg.into());
    let out = eng.call("predict", &inputs).unwrap();
    let logits = out[0].f32s();
    assert_eq!(logits.len(), b * c);
    assert!(logits.iter().all(|x| x.is_finite()));
}

#[test]
fn bad_input_arity_is_rejected() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let eng = Engine::open(&dir).unwrap();
    assert!(eng.call("predict", &[]).is_err());
}
