//! LDP-style structural node features (Local Degree Profile).
//!
//! MalNet ships no node attributes; the standard practice (followed by the
//! paper's GraphGym setup) is degree-derived features. We use a 16-dim
//! profile: an 8-bucket log2 degree one-hot plus 8 neighborhood statistics.

use crate::graph::Csr;

pub const LDP_DIM: usize = 16;

/// Compute the 16-dim LDP feature for every node of `g` and return a new
/// graph with those features installed.
pub fn with_ldp_features(g: &Csr) -> Csr {
    let n = g.num_nodes();
    let mut feats = vec![0f32; n * LDP_DIM];
    let max_deg = (0..n).map(|v| g.degree(v)).max().unwrap_or(1).max(1) as f32;
    for v in 0..n {
        let d = g.degree(v);
        let row = &mut feats[v * LDP_DIM..(v + 1) * LDP_DIM];
        // one-hot log2 degree bucket [0..8)
        let bucket = if d == 0 {
            0
        } else {
            (((d as f32).log2().floor() as usize) + 1).min(7)
        };
        row[bucket] = 1.0;
        // neighbor-degree statistics
        let nd: Vec<f32> =
            g.neighbors(v).iter().map(|&w| g.degree(w as usize) as f32).collect();
        let (mn, mx, mean, std) = if nd.is_empty() {
            (0.0, 0.0, 0.0, 0.0)
        } else {
            let mean = nd.iter().sum::<f32>() / nd.len() as f32;
            let var = nd.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
                / nd.len() as f32;
            (
                nd.iter().cloned().fold(f32::MAX, f32::min),
                nd.iter().cloned().fold(f32::MIN, f32::max),
                mean,
                var.sqrt(),
            )
        };
        row[8] = d as f32 / max_deg; // normalized own degree
        row[9] = (1.0 + d as f32).ln(); // log degree
        row[10] = mn / max_deg;
        row[11] = mx / max_deg;
        row[12] = mean / max_deg;
        row[13] = std / max_deg;
        // local clustering proxy: closed wedges among first ≤8 neighbors
        row[14] = clustering_proxy(g, v);
        row[15] = 1.0; // bias
    }
    Csr { offsets: g.offsets.clone(), adj: g.adj.clone(), feats, feat_dim: LDP_DIM }
}

fn clustering_proxy(g: &Csr, v: usize) -> f32 {
    let nb = g.neighbors(v);
    let k = nb.len().min(8);
    if k < 2 {
        return 0.0;
    }
    let mut closed = 0usize;
    let mut total = 0usize;
    for i in 0..k {
        for j in i + 1..k {
            total += 1;
            if g.has_edge(nb[i] as usize, nb[j] as usize) {
                closed += 1;
            }
        }
    }
    closed as f32 / total as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn ldp_dims_and_onehot() {
        let mut b = GraphBuilder::new(4, 0);
        b.add_edge(0, 1);
        b.add_edge(0, 2);
        b.add_edge(0, 3);
        let g = with_ldp_features(&b.build());
        assert_eq!(g.feat_dim, LDP_DIM);
        // hub has degree 3 -> bucket floor(log2 3)+1 = 2
        assert_eq!(g.feat(0)[2], 1.0);
        // leaves have degree 1 -> bucket 1
        assert_eq!(g.feat(1)[1], 1.0);
        // bias always set
        for v in 0..4 {
            assert_eq!(g.feat(v)[15], 1.0);
        }
    }

    #[test]
    fn clustering_detects_triangle() {
        let mut b = GraphBuilder::new(3, 0);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(2, 0);
        let g = with_ldp_features(&b.build());
        assert_eq!(g.feat(0)[14], 1.0);
    }

    #[test]
    fn isolated_node_is_finite() {
        let b = GraphBuilder::new(1, 0);
        let g = with_ldp_features(&b.build());
        assert!(g.feat(0).iter().all(|x| x.is_finite()));
        assert_eq!(g.feat(0)[0], 1.0); // degree-0 bucket
    }
}
