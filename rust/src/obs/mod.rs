//! Observability layer: phase-scoped spans, a counter/gauge registry,
//! staleness telemetry, a JSONL trace sink and machine-readable run
//! reports (DESIGN.md §8).
//!
//! Everything here is **execution-only**: the [`Recorder`] never feeds
//! back into training, and when no sink is configured (`record` off, no
//! trace file, no heartbeat) every telemetry method is a cheap no-op, so
//! trained parameters are bit-identical with observability on or off
//! (pinned by `tests/gst_core.rs`).
//!
//! One exception is deliberate: the per-step wall-clock timer is always
//! on, because `RunResult.step_ms` is a core output of every run
//! (Table 3), not an opt-in diagnostic.

pub mod analyze;
pub mod hist;
mod report;
mod sink;

pub use hist::Histogram;

use std::cell::Cell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::metrics::StepTimer;
use crate::util::json::Json;
use anyhow::Result;
use sink::TraceSink;

/// Sink configuration carried inside `TrainConfig` (all off by default,
/// which makes the recorder a no-op).
#[derive(Clone, Debug, Default)]
pub struct ObsConfig {
    /// Collect phase/staleness/cache telemetry for the run report even
    /// without a trace file (`--report-json` sets this).
    pub record: bool,
    /// JSONL trace-event stream path (`--trace-out`).
    pub trace_out: Option<String>,
    /// Print a heartbeat line to stderr every N optimizer steps
    /// (`--log-every`; 0 = off).
    pub log_every: usize,
}

/// The fixed phase taxonomy spans are attributed to. `Step` is the outer
/// span wrapping one optimizer step; the rest are its leaves (plus the
/// out-of-step `Eval` and `Finetune` phases).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Step,
    Sample,
    Fill,
    EmbedFwd,
    Grad,
    TableCommit,
    Eval,
    Finetune,
}

impl Phase {
    pub const ALL: [Phase; 8] = [
        Phase::Step,
        Phase::Sample,
        Phase::Fill,
        Phase::EmbedFwd,
        Phase::Grad,
        Phase::TableCommit,
        Phase::Eval,
        Phase::Finetune,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Step => "step",
            Phase::Sample => "sample",
            Phase::Fill => "fill",
            Phase::EmbedFwd => "embed_fwd",
            Phase::Grad => "grad",
            Phase::TableCommit => "table_commit",
            Phase::Eval => "eval",
            Phase::Finetune => "finetune",
        }
    }

    fn idx(self) -> usize {
        self as usize
    }

    /// `util::sync` wait-attribution slot of this phase (the lock
    /// wrappers are phase-agnostic; the discriminant *is* the slot, and
    /// slot [`crate::util::sync::UNTAGGED_SLOT`] stays reserved for
    /// waits outside any span).
    pub fn wait_slot(self) -> usize {
        self as usize
    }

    /// Phase name a `util::sync` wait slot aggregates under
    /// (`"untagged"` for the out-of-span slot).
    pub fn slot_name(slot: usize) -> &'static str {
        match Phase::ALL.get(slot) {
            Some(p) => p.name(),
            None => "untagged",
        }
    }
}

/// Per-epoch staleness snapshot sampled from the embedding table after
/// each training epoch.
#[derive(Clone, Debug)]
pub struct EpochStats {
    /// 1-based epoch index (matches `Curve.epochs`).
    pub epoch: usize,
    /// Fraction of table rows ever written.
    pub coverage: f64,
    /// Mean staleness over written rows, in optimizer steps.
    pub mean_staleness: f64,
    /// Staleness distribution over written rows.
    pub hist: Histogram,
}

thread_local! {
    /// Span nesting depth on this thread (worker threads start at 0).
    static DEPTH: Cell<u32> = Cell::new(0);
    /// Worker id tagging this thread's trace spans (-1 = coordinator /
    /// outside any fork-join compute region).
    static WORKER: Cell<i64> = Cell::new(-1);
}

/// Fork-join imbalance over per-worker busy times: `100·(1 − mean/max)`
/// — 0% when every worker was equally busy, approaching 100% when one
/// worker did all the work. Fewer than two workers report 0.
pub fn imbalance_pct(busy: &[f64]) -> f64 {
    if busy.len() < 2 {
        return 0.0;
    }
    let max = busy.iter().fold(0.0f64, |a, &b| a.max(b));
    if max <= 0.0 {
        return 0.0;
    }
    let mean = busy.iter().sum::<f64>() / busy.len() as f64;
    100.0 * (1.0 - mean / max)
}

/// Run-wide telemetry hub. All methods take `&self` (interior
/// mutability) and the type is `Sync`, so one recorder is shared by the
/// sequential plan/commit path and the parallel compute workers alike.
pub struct Recorder {
    enabled: bool,
    t0: Instant,
    log_every: u64,
    cur_step: AtomicU64,
    phase_ns: [AtomicU64; 8],
    phase_calls: [AtomicU64; 8],
    steps: Mutex<StepTimer>,
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    epochs: Mutex<Vec<EpochStats>>,
    /// cumulative per-worker compute busy time (ns), indexed by worker id
    worker_busy: Mutex<Vec<u64>>,
    /// fork-join regions recorded into `worker_busy`
    fork_joins: AtomicU64,
    /// latest cumulative lock-wait total pushed by the trainer (ns) —
    /// read by the heartbeat line and the run report
    lock_wait_ns: AtomicU64,
    sink: Option<TraceSink>,
}

impl Recorder {
    /// Recorder with every optional sink off (step timing still runs).
    pub fn disabled() -> Recorder {
        Recorder::build(false, 0, None)
    }

    /// Build from the run's [`ObsConfig`]; opening the trace file is the
    /// only fallible part.
    pub fn new(cfg: &ObsConfig) -> Result<Recorder> {
        let sink = match &cfg.trace_out {
            Some(path) => Some(TraceSink::create(path)?),
            None => None,
        };
        let enabled = cfg.record || sink.is_some() || cfg.log_every > 0;
        Ok(Recorder::build(enabled, cfg.log_every as u64, sink))
    }

    fn build(
        enabled: bool,
        log_every: u64,
        sink: Option<TraceSink>,
    ) -> Recorder {
        Recorder {
            enabled,
            t0: Instant::now(),
            log_every,
            cur_step: AtomicU64::new(0),
            phase_ns: std::array::from_fn(|_| AtomicU64::new(0)),
            phase_calls: std::array::from_fn(|_| AtomicU64::new(0)),
            steps: Mutex::new(StepTimer::default()),
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            epochs: Mutex::new(Vec::new()),
            worker_busy: Mutex::new(Vec::new()),
            fork_joins: AtomicU64::new(0),
            lock_wait_ns: AtomicU64::new(0),
            sink,
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    // -- step wall-clock (always on: RunResult.step_ms is a core output)

    pub fn step_start(&self) {
        self.steps.lock().unwrap().start();
    }

    /// Close the current step sample; prints the heartbeat line when
    /// `--log-every` is set.
    pub fn step_stop(&self) {
        let (recorded, count, last_ms, mean_ms) = {
            let mut t = self.steps.lock().unwrap();
            let before = t.count();
            t.stop();
            (t.count() > before, t.count(), t.last_ms(), t.mean_ms())
        };
        if !recorded {
            return; // paused section (finetune): nothing was sampled
        }
        if self.log_every > 0 && count as u64 % self.log_every == 0 {
            let imb = self.worker_imbalance_pct();
            let lw = self.lock_wait_ms();
            eprintln!(
                "[obs] step={count} last_ms={last_ms:.2} \
                 mean_ms={mean_ms:.2} imbalance={imb:.1}% \
                 lock_wait_ms={lw:.1}"
            );
        }
    }

    /// Enter an untimed section (the +F finetuning phase, which the
    /// paper's per-iteration numbers exclude).
    pub fn pause_steps(&self) {
        self.steps.lock().unwrap().pause();
    }

    /// Leave the untimed section.
    pub fn resume_steps(&self) {
        self.steps.lock().unwrap().resume();
    }

    pub fn step_count(&self) -> usize {
        self.steps.lock().unwrap().count()
    }

    pub fn step_mean_ms_from(&self, skip: usize) -> f64 {
        self.steps.lock().unwrap().mean_ms_from(skip)
    }

    pub fn step_p50_ms(&self) -> f64 {
        self.steps.lock().unwrap().p50_ms()
    }

    pub fn step_p95_ms(&self) -> f64 {
        self.steps.lock().unwrap().p95_ms()
    }

    pub fn step_max_ms(&self) -> f64 {
        self.steps.lock().unwrap().max_ms()
    }

    // -- spans, counters, gauges, points (no-ops when disabled) --

    /// Tag subsequent trace events with the current optimizer-step index.
    pub fn set_step(&self, step: u64) {
        if self.enabled {
            self.cur_step.store(step, Ordering::Relaxed);
        }
    }

    /// Tag this thread's trace spans with `worker` until the returned
    /// guard drops (the fork-join compute region). Inert when disabled.
    pub fn worker_scope(&self, worker: usize) -> WorkerScope {
        if !self.enabled {
            return WorkerScope { prev: None };
        }
        let prev = WORKER.with(|w| {
            let p = w.get();
            w.set(worker as i64);
            p
        });
        WorkerScope { prev: Some(prev) }
    }

    /// Record one fork-join region's per-worker busy times (ns, indexed
    /// by worker id). Accumulates the run-wide per-worker busy totals
    /// behind [`Recorder::worker_busy_ms`] / the imbalance gauge.
    pub fn record_fork_join(&self, busy_ns: &[u64]) {
        if !self.enabled || busy_ns.is_empty() {
            return;
        }
        let mut busy = self.worker_busy.lock().unwrap();
        if busy.len() < busy_ns.len() {
            busy.resize(busy_ns.len(), 0);
        }
        for (total, &ns) in busy.iter_mut().zip(busy_ns) {
            *total += ns;
        }
        self.fork_joins.fetch_add(1, Ordering::Relaxed);
    }

    /// Cumulative per-worker compute busy time, ms, indexed by worker id.
    pub fn worker_busy_ms(&self) -> Vec<f64> {
        self.worker_busy
            .lock()
            .unwrap()
            .iter()
            .map(|&ns| ns as f64 / 1e6)
            .collect()
    }

    /// Fork-join imbalance over the cumulative per-worker busy times.
    pub fn worker_imbalance_pct(&self) -> f64 {
        imbalance_pct(&self.worker_busy_ms())
    }

    /// Latest cumulative lock-wait total (pushed by the trainer from the
    /// engine / fill-cache timed locks each step).
    pub fn set_lock_wait_ns(&self, ns: u64) {
        if self.enabled {
            self.lock_wait_ns.store(ns, Ordering::Relaxed);
        }
    }

    pub fn lock_wait_ms(&self) -> f64 {
        self.lock_wait_ns.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// RAII phase timer; returns an inert guard when disabled. Guards
    /// nest: each carries the depth at which it was opened.
    pub fn span(&self, phase: Phase) -> Span<'_> {
        if !self.enabled {
            return Span { inner: None };
        }
        let depth = DEPTH.with(|d| {
            let v = d.get();
            d.set(v + 1);
            v
        });
        // charge this thread's blocked lock waits to the span's phase
        // (restored on drop so nested spans attribute correctly)
        let prev_wait_slot =
            crate::util::sync::swap_wait_phase(phase.wait_slot());
        Span {
            inner: Some(SpanInner {
                rec: self,
                phase,
                start: Instant::now(),
                depth,
                prev_wait_slot,
            }),
        }
    }

    /// Add to a named counter.
    pub fn add(&self, name: &str, n: u64) {
        if self.enabled && n > 0 {
            *self
                .counters
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_insert(0) += n;
        }
    }

    /// Read a counter back (0 when absent or disabled).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Set a named gauge to its latest value.
    pub fn gauge(&self, name: &str, value: f64) {
        if self.enabled {
            self.gauges.lock().unwrap().insert(name.to_string(), value);
        }
    }

    /// Record one epoch's staleness snapshot (also emitted as a trace
    /// point when a sink is attached, together with an `epoch_sed` point
    /// carrying the cumulative SED counters so trace analysis can
    /// compute per-epoch drop-rate drift).
    pub fn record_epoch(&self, stats: EpochStats) {
        if !self.enabled {
            return;
        }
        self.point(
            "epoch_staleness",
            Json::obj(vec![
                ("epoch", Json::num(stats.epoch as f64)),
                ("coverage", Json::num(stats.coverage)),
                ("mean", Json::num(stats.mean_staleness)),
            ]),
        );
        self.point(
            "epoch_sed",
            Json::obj(vec![
                ("epoch", Json::num(stats.epoch as f64)),
                (
                    "stale_total",
                    Json::num(self.counter("sed_stale_total") as f64),
                ),
                (
                    "stale_dropped",
                    Json::num(self.counter("sed_stale_dropped") as f64),
                ),
            ]),
        );
        self.epochs.lock().unwrap().push(stats);
    }

    /// Emit a named point event to the trace sink, if any.
    pub fn point(&self, name: &str, data: Json) {
        let Some(sink) = &self.sink else { return };
        sink.write(&Json::obj(vec![
            ("ev", Json::str("point")),
            ("name", Json::str(name)),
            ("t_us", Json::num(self.t_us())),
            ("data", data),
        ]));
    }

    /// Flush the trace sink (end of run).
    pub fn flush(&self) {
        if let Some(sink) = &self.sink {
            sink.flush();
        }
    }

    fn t_us(&self) -> f64 {
        self.t0.elapsed().as_micros() as f64
    }
}

/// RAII guard from [`Recorder::worker_scope`]: restores the thread's
/// previous worker tag on drop (scopes nest, e.g. the inline
/// single-worker fast path running on the coordinator thread).
pub struct WorkerScope {
    prev: Option<i64>,
}

impl Drop for WorkerScope {
    fn drop(&mut self) {
        if let Some(prev) = self.prev {
            WORKER.with(|w| w.set(prev));
        }
    }
}

/// RAII guard from [`Recorder::span`]: measures wall-clock from creation
/// to drop and attributes it to the span's phase.
pub struct Span<'a> {
    inner: Option<SpanInner<'a>>,
}

struct SpanInner<'a> {
    rec: &'a Recorder,
    phase: Phase,
    start: Instant,
    depth: u32,
    /// wait-attribution slot to restore when this span closes
    prev_wait_slot: usize,
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        let Some(s) = self.inner.take() else { return };
        DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
        crate::util::sync::swap_wait_phase(s.prev_wait_slot);
        let ns = s.start.elapsed().as_nanos() as u64;
        let i = s.phase.idx();
        s.rec.phase_ns[i].fetch_add(ns, Ordering::Relaxed);
        s.rec.phase_calls[i].fetch_add(1, Ordering::Relaxed);
        if let Some(sink) = &s.rec.sink {
            let step = s.rec.cur_step.load(Ordering::Relaxed);
            let t_us =
                s.start.duration_since(s.rec.t0).as_micros() as f64;
            let mut fields = vec![
                ("ev", Json::str("span")),
                ("phase", Json::str(s.phase.name())),
                ("step", Json::num(step as f64)),
                ("t_us", Json::num(t_us)),
                ("dur_us", Json::num(ns as f64 / 1e3)),
                ("depth", Json::num(s.depth as f64)),
            ];
            let worker = WORKER.with(|w| w.get());
            if worker >= 0 {
                fields.push(("worker", Json::num(worker as f64)));
            }
            sink.write(&Json::obj(fields));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_is_send_and_sync() {
        fn assert_sync<T: Send + Sync>() {}
        assert_sync::<Recorder>();
    }

    #[test]
    fn disabled_recorder_is_inert() {
        let r = Recorder::disabled();
        assert!(!r.is_enabled());
        {
            let _outer = r.span(Phase::Step);
            let _inner = r.span(Phase::Fill);
        }
        r.add("x", 3);
        r.gauge("g", 1.0);
        assert_eq!(r.counter("x"), 0);
        let j = r.phases_json();
        for p in Phase::ALL {
            assert_eq!(j.at(p.name()).at("calls").as_f64(), Some(0.0));
        }
        // ...but the step timer still runs (step_ms is a core output)
        r.step_start();
        r.step_stop();
        assert_eq!(r.step_count(), 1);
    }

    #[test]
    fn spans_nest_and_accumulate() {
        let r = Recorder::new(&ObsConfig {
            record: true,
            ..ObsConfig::default()
        })
        .unwrap();
        {
            let _step = r.span(Phase::Step);
            {
                let _fill = r.span(Phase::Fill);
                std::hint::black_box((0..10_000).sum::<u64>());
            }
            {
                let _grad = r.span(Phase::Grad);
            }
        }
        let j = r.phases_json();
        assert_eq!(j.at("step").at("calls").as_f64(), Some(1.0));
        assert_eq!(j.at("fill").at("calls").as_f64(), Some(1.0));
        assert_eq!(j.at("grad").at("calls").as_f64(), Some(1.0));
        let step_ms = j.at("step").at("total_ms").as_f64().unwrap();
        let fill_ms = j.at("fill").at("total_ms").as_f64().unwrap();
        let grad_ms = j.at("grad").at("total_ms").as_f64().unwrap();
        // the outer span covers both inner ones
        assert!(step_ms >= fill_ms + grad_ms);
    }

    #[test]
    fn imbalance_formula_edge_cases() {
        assert_eq!(imbalance_pct(&[]), 0.0);
        assert_eq!(imbalance_pct(&[5.0]), 0.0);
        assert_eq!(imbalance_pct(&[3.0, 3.0]), 0.0);
        assert_eq!(imbalance_pct(&[0.0, 0.0]), 0.0);
        // one worker idle: mean = max/2 -> 50%
        assert!((imbalance_pct(&[4.0, 0.0]) - 50.0).abs() < 1e-12);
        // 2100 vs 1650 us -> 100 * (1 - 1875/2100)
        let v = imbalance_pct(&[2.1, 1.65]);
        assert!((v - 100.0 * (1.0 - 1.875 / 2.1)).abs() < 1e-9);
    }

    #[test]
    fn fork_join_accumulates_per_worker_busy() {
        let r = Recorder::new(&ObsConfig {
            record: true,
            ..ObsConfig::default()
        })
        .unwrap();
        r.record_fork_join(&[3_000_000, 1_000_000]);
        r.record_fork_join(&[1_000_000, 1_000_000]);
        let busy = r.worker_busy_ms();
        assert_eq!(busy.len(), 2);
        assert!((busy[0] - 4.0).abs() < 1e-9);
        assert!((busy[1] - 2.0).abs() < 1e-9);
        // cumulative: mean 3, max 4 -> 25%
        assert!((r.worker_imbalance_pct() - 25.0).abs() < 1e-9);
        let j = r.workers_json();
        assert_eq!(j.at("count").as_f64(), Some(2.0));
        assert_eq!(j.at("fork_joins").as_f64(), Some(2.0));
    }

    #[test]
    fn disabled_recorder_ignores_worker_telemetry() {
        let r = Recorder::disabled();
        let _scope = r.worker_scope(3);
        r.record_fork_join(&[1_000, 2_000]);
        r.set_lock_wait_ns(5_000_000);
        assert!(r.worker_busy_ms().is_empty());
        assert_eq!(r.worker_imbalance_pct(), 0.0);
        assert_eq!(r.lock_wait_ms(), 0.0);
    }

    #[test]
    fn worker_scopes_nest_and_restore() {
        let r = Recorder::new(&ObsConfig {
            record: true,
            ..ObsConfig::default()
        })
        .unwrap();
        assert_eq!(WORKER.with(|w| w.get()), -1);
        {
            let _outer = r.worker_scope(0);
            assert_eq!(WORKER.with(|w| w.get()), 0);
            {
                let _inner = r.worker_scope(2);
                assert_eq!(WORKER.with(|w| w.get()), 2);
            }
            assert_eq!(WORKER.with(|w| w.get()), 0);
        }
        assert_eq!(WORKER.with(|w| w.get()), -1);
    }

    #[test]
    fn spans_tag_lock_waits_with_their_phase() {
        use crate::util::sync;
        let r = Recorder::new(&ObsConfig {
            record: true,
            ..ObsConfig::default()
        })
        .unwrap();
        assert_eq!(sync::current_wait_phase(), sync::UNTAGGED_SLOT);
        {
            let _grad = r.span(Phase::Grad);
            assert_eq!(
                sync::current_wait_phase(),
                Phase::Grad.wait_slot()
            );
            {
                let _commit = r.span(Phase::TableCommit);
                assert_eq!(
                    sync::current_wait_phase(),
                    Phase::TableCommit.wait_slot()
                );
            }
            assert_eq!(
                sync::current_wait_phase(),
                Phase::Grad.wait_slot()
            );
        }
        assert_eq!(sync::current_wait_phase(), sync::UNTAGGED_SLOT);
        // a disabled recorder never tags (its spans are inert)
        let off = Recorder::disabled();
        let _s = off.span(Phase::Fill);
        assert_eq!(sync::current_wait_phase(), sync::UNTAGGED_SLOT);
    }

    #[test]
    fn slot_names_cover_every_phase_plus_untagged() {
        for p in Phase::ALL {
            assert_eq!(Phase::slot_name(p.wait_slot()), p.name());
        }
        assert_eq!(
            Phase::slot_name(crate::util::sync::UNTAGGED_SLOT),
            "untagged"
        );
    }

    #[test]
    fn counters_and_gauges_accumulate_when_enabled() {
        let r = Recorder::new(&ObsConfig {
            record: true,
            ..ObsConfig::default()
        })
        .unwrap();
        r.add("sed_stale_total", 2);
        r.add("sed_stale_total", 3);
        r.add("zero", 0);
        r.gauge("mem", 1.5);
        r.gauge("mem", 2.5);
        assert_eq!(r.counter("sed_stale_total"), 5);
        assert_eq!(r.counter("zero"), 0);
        let g = r.gauges_json();
        assert_eq!(g.at("mem").as_f64(), Some(2.5));
    }
}
