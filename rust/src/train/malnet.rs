//! MalNet trainer: 5-way graph classification (Tables 1, 3; Figs 2, 3, 4, 6).

use super::ops::{self, BatchBufs};
use super::{Method, RunResult, SedMode, TrainConfig};
use crate::datasets::MalnetDataset;
use crate::metrics::{self, Curve, StepTimer};
use crate::partition::Algorithm;
use crate::runtime::{Engine, ParamStore};
use crate::sed;
use crate::segment::SegmentedGraph;
use crate::table::EmbeddingTable;
use crate::util::rng::Pcg64;
use anyhow::{bail, Result};

pub struct MalnetTrainer<'a> {
    eng: &'a Engine,
    data: &'a MalnetDataset,
    pub cfg: TrainConfig,
    pub ps: ParamStore,
    segs: Vec<SegmentedGraph>,
    pub table: EmbeddingTable,
    rng: Pcg64,
    step: u32,
    /// steps recorded during the first epoch (cold-table warmup)
    first_epoch_steps: usize,
    pub timer: StepTimer,
}

impl<'a> MalnetTrainer<'a> {
    /// Partition every graph and set up state. Errors with "OOM" if the
    /// method is FullGraph and any training graph exceeds the memory
    /// budget (more segments than the full-step artifact has slots — the
    /// scaled analogue of the paper's 16 GB boundary, see memory/).
    pub fn new(
        eng: &'a Engine,
        data: &'a MalnetDataset,
        cfg: TrainConfig,
    ) -> Result<MalnetTrainer<'a>> {
        assert_eq!(eng.manifest.dataset, "malnet");
        assert_eq!(
            cfg.s_per_graph, 1,
            "the AOT grad_step samples S=1 segment per graph slot (paper's setting)"
        );
        let mut rng = Pcg64::new(cfg.seed, 0x7261).stream("partition");
        let max = eng.manifest.max_nodes;
        let mut segs: Vec<SegmentedGraph> = data
            .graphs
            .iter()
            .map(|g| {
                let set = cfg.partition.partition(g, max, &mut rng);
                SegmentedGraph::new(g, &set)
            })
            .collect();
        if cfg.method == Method::FullGraph {
            // Full Graph Training backprops through ALL segments, so the
            // partition's only job is memory packing. When the configured
            // partitioner leaves slack (slivers), retry with BFS — which
            // fills segments to exactly max_nodes — before declaring OOM.
            let jmax = eng.manifest.full_jmax;
            for (i, g) in data.graphs.iter().enumerate() {
                if segs[i].num_segments() > jmax {
                    let packed = Algorithm::EdgeCutBfs
                        .partition(g, max, &mut rng);
                    segs[i] = SegmentedGraph::new(g, &packed);
                }
                if segs[i].num_segments() > jmax {
                    bail!(
                        "OOM: Full Graph Training needs {} live segments \
                         for graph {i} but the memory budget holds {jmax} \
                         (paper: 16 GB V100 boundary)",
                        segs[i].num_segments()
                    );
                }
            }
        }
        let counts: Vec<usize> =
            segs.iter().map(|s| s.num_segments()).collect();
        let table = EmbeddingTable::new(&counts, eng.manifest.table_dim);
        let ps = ParamStore::load(eng.dir(), &eng.manifest)?;
        // compile up front so step timings (Table 3) exclude compilation
        let mut fns = vec!["grad_step", "apply_step", "embed_fwd", "predict"];
        if cfg.method == Method::FullGraph {
            fns.push("full_step");
        }
        if cfg.method.finetunes() {
            fns.extend(["head_grad_step", "head_apply_step"]);
        }
        eng.warmup(&fns)?;
        Ok(MalnetTrainer {
            eng,
            data,
            cfg: cfg.clone(),
            ps,
            segs,
            table,
            rng: Pcg64::new(cfg.seed, 0x7261),
            step: 0,
            first_epoch_steps: 0,
            timer: StepTimer::default(),
        })
    }

    fn lr(&self) -> f32 {
        self.cfg.lr.unwrap_or(self.eng.manifest.lr)
    }

    /// Total segments across the dataset (observability).
    pub fn total_segments(&self) -> usize {
        self.segs.iter().map(|s| s.num_segments()).sum()
    }

    /// Run the full schedule: `epochs` of GST training, then (for +F
    /// methods) the finetuning phase, recording the accuracy curve.
    pub fn train(&mut self) -> Result<RunResult> {
        let mut curve = Curve::default();
        let eval_train = self.eval_subset(&self.data.train, 40);
        for epoch in 0..self.cfg.epochs {
            if self.cfg.method == Method::FullGraph {
                self.full_graph_epoch()?;
            } else {
                self.gst_epoch()?;
            }
            if epoch == 0 {
                self.first_epoch_steps = self.timer.count();
            }
            if (epoch + 1) % self.cfg.eval_every == 0
                || epoch + 1 == self.cfg.epochs
            {
                let (tr, _) = self.evaluate(&eval_train)?;
                let (te, _) = self.evaluate(&self.data.test)?;
                curve.push(epoch + 1, tr, te);
            }
        }
        if self.cfg.method.finetunes() {
            self.finetune(&mut curve, &eval_train)?;
        }
        let (train_metric, _) = self.evaluate(&eval_train)?;
        let (test_metric, _) = self.evaluate(&self.data.test)?;
        Ok(RunResult {
            train_metric,
            test_metric,
            // steady-state: exclude the first epoch's cold-table steps
            step_ms: self.timer.mean_ms_from(self.first_epoch_steps),
            curve,
            call_counts: self.eng.call_counts(),
        })
    }

    fn eval_subset(&self, idx: &[usize], cap: usize) -> Vec<usize> {
        idx.iter().take(cap).copied().collect()
    }

    // -- GST family ---------------------------------------------------------

    fn gst_epoch(&mut self) -> Result<()> {
        let b = self.eng.manifest.batch;
        let mut order = self.data.train.clone();
        let mut rng = self.rng.stream(&format!("epoch{}", self.step));
        rng.shuffle(&mut order);
        let mut micro: Vec<Vec<Vec<f32>>> = Vec::new();
        for chunk in order.chunks(b) {
            if chunk.len() < b {
                break; // drop_last, standard minibatch SGD
            }
            self.timer.start();
            let grads = self.gst_step(chunk, &mut rng)?;
            micro.push(grads);
            if micro.len() == self.cfg.workers {
                let avg = ops::average_grads(&micro);
                let lr = self.lr();
                ops::apply(self.eng, &mut self.ps, &avg, lr)?;
                micro.clear();
            }
            self.timer.stop();
            self.step += 1;
        }
        Ok(())
    }

    /// One grad_step over a batch of graphs (each contributing one sampled
    /// segment) — the heart of Algorithm 1/2.
    fn gst_step(
        &mut self,
        graphs: &[usize],
        rng: &mut Pcg64,
    ) -> Result<Vec<Vec<f32>>> {
        let m = &self.eng.manifest;
        let (b, td) = (m.batch, m.table_dim);
        let mut bufs = BatchBufs::new(self.eng);
        let mut sampled = vec![0usize; b];
        // needed[slot] = stale segments to aggregate as (seg_idx, eta)
        let mut needed: Vec<Vec<(usize, f32)>> = vec![Vec::new(); b];
        // fresh computes required before the step: (slot, graph, seg, eta)
        let mut fresh: Vec<(usize, usize, usize, f32)> = Vec::new();

        for (slot, &g) in graphs.iter().enumerate() {
            let j = self.segs[g].num_segments();
            let s = rng.below(j);
            sampled[slot] = s;
            let w = match self.cfg.method.sed(self.cfg.keep_p) {
                SedMode::KeepAll => sed::keep_all(j, &[s]),
                SedMode::DropAll => sed::drop_all(j, &[s]),
                SedMode::Draw(p) => sed::draw(j, &[s], p, rng),
            };
            bufs.eta[slot] = w.eta_fresh;
            bufs.invj[slot] = 1.0 / j as f32;
            bufs.labels[slot] = self.data.labels[g] as i32;
            let (nodes, adj, mask) = bufs.slot(self.eng, slot);
            self.segs[g].fill_padded(
                &self.data.graphs[g], s, m.adj_norm, m.max_nodes, m.feat,
                None, nodes, adj, mask,
            );
            for (seg, &eta) in w.eta_stale.iter().enumerate() {
                if seg == s || eta == 0.0 {
                    continue;
                }
                if self.cfg.method.fresh_stale() {
                    fresh.push((slot, g, seg, eta));
                } else if self.table.get(g, seg).is_some() {
                    needed[slot].push((seg, eta));
                } else {
                    // cold table entry (first epoch): compute fresh AND
                    // write it back, exactly like Alg. 2's first touch
                    fresh.push((slot, g, seg, eta));
                }
            }
        }
        // batch-compute the fresh stale embeddings
        if !fresh.is_empty() {
            let pairs: Vec<(usize, usize)> =
                fresh.iter().map(|&(_, g, s, _)| (g, s)).collect();
            let embs = self.embed_many(&pairs)?;
            for ((slot, g, seg, eta), h) in fresh.iter().zip(&embs) {
                for d in 0..td {
                    bufs.stale[slot * td + d] += eta * h[d];
                }
                if self.cfg.method.uses_table() {
                    self.table.put(*g, *seg, h, self.step);
                }
            }
        }
        // add the table-served stale embeddings
        for (slot, &g) in graphs.iter().enumerate() {
            for &(seg, eta) in &needed[slot] {
                let h = self.table.get(g, seg).expect("checked above");
                for d in 0..td {
                    bufs.stale[slot * td + d] += eta * h[d];
                }
            }
        }
        let out = ops::grad_step(self.eng, &self.ps, &bufs)?;
        // write back the fresh sampled-segment embeddings (Alg. 2 line 7)
        if self.cfg.method.uses_table() {
            for (slot, &g) in graphs.iter().enumerate() {
                let h = &out.h_s[slot * td..(slot + 1) * td];
                self.table.put(g, sampled[slot], h, self.step);
            }
        }
        Ok(out.grads)
    }

    // -- Full Graph Training baseline ----------------------------------------

    fn full_graph_epoch(&mut self) -> Result<()> {
        let b = self.eng.manifest.batch;
        let mut order = self.data.train.clone();
        let mut rng = self.rng.stream(&format!("full{}", self.step));
        rng.shuffle(&mut order);
        for chunk in order.chunks(b) {
            if chunk.len() < b {
                break;
            }
            self.timer.start();
            let mut sets = Vec::with_capacity(chunk.len());
            for &g in chunk {
                sets.push(self.full_step_one(g)?.grads);
            }
            let avg = ops::average_grads(&sets);
            let lr = self.lr();
            ops::apply(self.eng, &mut self.ps, &avg, lr)?;
            self.timer.stop();
            self.step += 1;
        }
        Ok(())
    }

    fn full_step_one(&mut self, g: usize) -> Result<ops::StepOut> {
        let m = &self.eng.manifest;
        let (jm, n, f) = (m.full_jmax, m.max_nodes, m.feat);
        let j = self.segs[g].num_segments();
        assert!(j <= jm, "checked at construction");
        let mut nodes = vec![0f32; jm * n * f];
        let mut adj = vec![0f32; jm * n * n];
        let mut mask = vec![0f32; jm * n];
        let mut seg_mask = vec![0f32; jm];
        for s in 0..j {
            self.segs[g].fill_padded(
                &self.data.graphs[g], s, m.adj_norm, n, f, None,
                &mut nodes[s * n * f..(s + 1) * n * f],
                &mut adj[s * n * n..(s + 1) * n * n],
                &mut mask[s * n..(s + 1) * n],
            );
            seg_mask[s] = 1.0;
        }
        ops::full_step(
            self.eng, &self.ps, &nodes, &adj, &mask, &seg_mask,
            self.data.labels[g] as i32,
        )
    }

    // -- shared helpers -------------------------------------------------------

    /// Fresh embeddings for a list of (graph, segment) pairs, batched
    /// through `embed_fwd` (pads the last chunk by repeating entry 0).
    pub fn embed_many(
        &self,
        pairs: &[(usize, usize)],
    ) -> Result<Vec<Vec<f32>>> {
        let m = &self.eng.manifest;
        let (b, n, f, td) = (m.batch, m.max_nodes, m.feat, m.table_dim);
        let mut out = Vec::with_capacity(pairs.len());
        let mut nodes = vec![0f32; b * n * f];
        let mut adj = vec![0f32; b * n * n];
        let mut mask = vec![0f32; b * n];
        for chunk in pairs.chunks(b) {
            for slot in 0..b {
                let (g, s) = chunk[slot.min(chunk.len() - 1)];
                self.segs[g].fill_padded(
                    &self.data.graphs[g], s, m.adj_norm, n, f, None,
                    &mut nodes[slot * n * f..(slot + 1) * n * f],
                    &mut adj[slot * n * n..(slot + 1) * n * n],
                    &mut mask[slot * n..(slot + 1) * n],
                );
            }
            let h = ops::embed_fwd(self.eng, &self.ps, &nodes, &adj, &mask)?;
            for slot in 0..chunk.len() {
                out.push(h[slot * td..(slot + 1) * td].to_vec());
            }
        }
        Ok(out)
    }

    /// Test-time evaluation: fresh embeddings for every segment, mean pool,
    /// head (P_test in §3.3). Returns (accuracy, mean CE loss).
    pub fn evaluate(&self, graphs: &[usize]) -> Result<(f64, f64)> {
        let m = &self.eng.manifest;
        let (b, h, td) = (m.batch, m.hidden, m.table_dim);
        assert_eq!(h, td);
        // graph embeddings
        let mut h_graphs = Vec::with_capacity(graphs.len());
        for &g in graphs {
            let pairs: Vec<(usize, usize)> = (0..self.segs[g].num_segments())
                .map(|s| (g, s))
                .collect();
            let embs = self.embed_many(&pairs)?;
            let mut agg = vec![0f32; td];
            for e in &embs {
                for d in 0..td {
                    agg[d] += e[d];
                }
            }
            for d in agg.iter_mut() {
                *d /= pairs.len() as f32;
            }
            h_graphs.push(agg);
        }
        // head in batches
        let head_idx = m.head_indices();
        let mut logits = Vec::with_capacity(graphs.len());
        for chunk in h_graphs.chunks(b) {
            let mut packed = vec![0f32; b * h];
            for (slot, hg) in chunk.iter().enumerate() {
                packed[slot * h..(slot + 1) * h].copy_from_slice(hg);
            }
            let lg = ops::predict(self.eng, &self.ps, &head_idx, &packed)?;
            let c = m.classes;
            for slot in 0..chunk.len() {
                logits.push(lg[slot * c..(slot + 1) * c].to_vec());
            }
        }
        let labels: Vec<u8> =
            graphs.iter().map(|&g| self.data.labels[g]).collect();
        Ok((
            metrics::accuracy(&logits, &labels),
            metrics::cross_entropy(&logits, &labels),
        ))
    }

    // -- Prediction Head Finetuning (+F, Alg. 2 lines 11-18) ------------------

    fn finetune(
        &mut self,
        curve: &mut Curve,
        eval_train: &[usize],
    ) -> Result<()> {
        let m = &self.eng.manifest;
        let (b, h) = (m.batch, m.hidden);
        // 1. refresh every table row with the current backbone F
        let mut pairs = Vec::new();
        for &g in &self.data.train {
            for s in 0..self.segs[g].num_segments() {
                pairs.push((g, s));
            }
        }
        let embs = self.embed_many(&pairs)?;
        for ((g, s), e) in pairs.iter().zip(&embs) {
            self.table.put(*g, *s, e, self.step);
        }
        // 2. finetune only F' on up-to-date mean-pooled embeddings, with a
        //    fresh Adam state (the backbone stays frozen)
        let head_idx = m.head_indices();
        let mut head = self.ps.subset(&head_idx);
        head.t = 0;
        for x in head.m.iter_mut().chain(head.v.iter_mut()) {
            x.fill(0.0);
        }
        let mut rng = self.rng.stream("finetune");
        for ft_epoch in 0..self.cfg.finetune_epochs {
            let mut order = self.data.train.clone();
            rng.shuffle(&mut order);
            for chunk in order.chunks(b) {
                if chunk.len() < b {
                    break;
                }
                self.timer_start_finetune();
                let mut hg = vec![0f32; b * h];
                let mut labels = vec![0i32; b];
                for (slot, &g) in chunk.iter().enumerate() {
                    let j = self.segs[g].num_segments();
                    for s in 0..j {
                        let e = self.table.get(g, s).expect("refreshed");
                        for d in 0..h {
                            hg[slot * h + d] += e[d] / j as f32;
                        }
                    }
                    labels[slot] = self.data.labels[g] as i32;
                }
                let (_loss, grads) =
                    ops::head_grad_step(self.eng, &head, &hg, &labels)?;
                ops::apply_named(
                    self.eng, "head_apply_step", &mut head, &grads,
                    m.head_lr,
                )?;
                self.step += 1;
            }
            // make the updated head visible to evaluate()
            self.ps.write_subset(&head_idx, &head);
            let (tr, _) = self.evaluate(eval_train)?;
            let (te, _) = self.evaluate(&self.data.test)?;
            curve.push(self.cfg.epochs + ft_epoch + 1, tr, te);
        }
        Ok(())
    }

    fn timer_start_finetune(&mut self) {
        // finetune steps are not counted in the Table 3 per-iteration time
        // (the paper reports the main-loop fwd+bwd time)
    }
}
