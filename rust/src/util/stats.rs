//! Small statistics helpers shared by metrics, benches and experiments.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (n-1 denominator; 0.0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (xs.len() - 1) as f64)
        .sqrt()
}

/// p-th percentile (0..=100) via linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Largest sample (0.0 for empty, to match the other helpers).
pub fn max(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Trimmed mean dropping the `frac` smallest and largest samples each —
/// the bench harness's outlier-resistant point estimate.
pub fn trimmed_mean(xs: &[f64], frac: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let k = ((v.len() as f64) * frac).floor() as usize;
    let trimmed = &v[k..v.len() - k.min(v.len() - 1)];
    mean(trimmed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn max_of_samples() {
        assert_eq!(max(&[]), 0.0);
        assert_eq!(max(&[3.0, -1.0, 2.0]), 3.0);
        assert_eq!(max(&[-3.0, -1.0]), -1.0);
    }

    #[test]
    fn trimmed_mean_resists_outliers() {
        let xs = [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 100.0, -50.0];
        let tm = trimmed_mean(&xs, 0.1);
        assert!((tm - 1.0).abs() < 1e-9, "tm={tm}");
    }
}
