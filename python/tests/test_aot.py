"""AOT pipeline: manifest integrity + lowered-HLO round-trip execution.

The round-trip check executes the exact HLO text rust will load (via the
jax CPU client) and compares against the eager python function — if this
passes, any rust-side numeric divergence is a marshalling bug, not a
lowering bug.
"""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model
from compile.config import VariantConfig, default_variants

jax.config.update("jax_platform_name", "cpu")

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

SMALL = VariantConfig("malnet", "sage", max_nodes=32, batch=2)


def test_default_variants_unique_names():
    names = [v.name for v in default_variants()]
    assert len(names) == len(set(names))


def test_variant_adj_norm_convention():
    assert VariantConfig("malnet", "gcn").adj_norm == "sym_selfloop"
    assert VariantConfig("malnet", "sage").adj_norm == "row_mean"
    assert VariantConfig("malnet", "gps").adj_norm == "row_mean"


def test_manifest_specs_cover_all_params():
    p = model.init_params(SMALL)
    fns = model.function_set(SMALL, p)
    names = model.param_order(p)
    _, in_specs, out_specs = fns["grad_step"]
    in_names = [s["name"] for s in in_specs]
    assert in_names[: len(names)] == [f"param:{k}" for k in names]
    out_names = [s["name"] for s in out_specs]
    assert out_names[0] == "loss" and out_names[-1] == "h_s"
    assert out_names[1:-1] == [f"grad:{k}" for k in names]


def test_apply_step_output_order_matches_param_m_v():
    p = model.init_params(SMALL)
    _, in_specs, out_specs = model.build_apply_step(SMALL, p)
    n = len(model.param_order(p))
    assert len(in_specs) == 4 * n + 2
    assert len(out_specs) == 3 * n


@pytest.mark.parametrize(
    "fname", ["embed_fwd", "grad_step", "apply_step", "head_grad_step",
              "predict"])
def test_roundtrip_small_variant(fname):
    """Lower + execute via XLA + compare vs eager (the rust-bound artifact)."""
    p = model.init_params(SMALL)
    fns = model.function_set(SMALL, p)
    fn, in_specs, out_specs = fns[fname]
    text = aot.lower_fn(fn, in_specs)
    assert text.startswith("HloModule")
    aot._roundtrip_check(fn, in_specs, out_specs, text, fname)


def test_built_artifacts_manifest_consistency():
    """For every variant already built under artifacts/, the manifest, the
    params blob and the HLO files must agree."""
    if not os.path.isdir(ART):
        pytest.skip("artifacts/ not built")
    for vname in sorted(os.listdir(ART)):
        mpath = os.path.join(ART, vname, "manifest.json")
        if not os.path.isfile(mpath):
            continue
        with open(mpath) as f:
            man = json.load(f)
        nbytes = sum(
            4 * int(np.prod(p["shape"] or [1])) for p in man["params"])
        blob = os.path.getsize(os.path.join(ART, vname, "init_params.bin"))
        assert blob == nbytes, vname
        for fname, fman in man["functions"].items():
            path = os.path.join(ART, vname, fman["file"])
            assert os.path.isfile(path), path
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), path


def test_init_params_blob_roundtrip(tmp_path):
    man = aot.build_variant(SMALL, str(tmp_path))
    p = model.init_params(SMALL, seed=0)
    blob = np.fromfile(
        os.path.join(tmp_path, SMALL.name, "init_params.bin"), np.float32)
    off = 0
    for spec in man["params"]:
        size = int(np.prod(spec["shape"] or [1]))
        got = blob[off:off + size].reshape(spec["shape"])
        np.testing.assert_array_equal(got, p[spec["name"]])
        off += size
    assert off == blob.size
