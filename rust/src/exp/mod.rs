//! Experiment drivers — one per table/figure in the paper's evaluation
//! (the DESIGN.md §5 index). Invoked via `gst experiment --id <name>`.

pub mod common;
pub mod figs;
pub mod scaling;
pub mod tables;

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use common::Env;

pub const ALL_IDS: [&str; 11] = [
    "table1", "table2", "table3", "table4", "table6", "fig2", "fig3",
    "fig4", "fig5", "fig6", "scaling",
];

/// Run one experiment by id. Every training run inside it records a
/// `gst-run-report/v1` document; the batch is written alongside the
/// experiment record as `<out>/<id>.reports.json`.
pub fn run(id: &str, env: &Env) -> Result<()> {
    env.reports.borrow_mut().clear();
    match id {
        "table1" => tables::table1(env),
        "table2" => tables::table2(env),
        "table3" => tables::table3(env),
        "table4" => tables::table4(env),
        "table6" => tables::table6(env),
        "fig2" => figs::fig2(env),
        "fig3" => figs::fig3(env),
        "fig4" => figs::fig4(env),
        "fig5" => figs::fig5(env),
        "fig6" => figs::fig6(env),
        "scaling" => scaling::scaling(env),
        other => bail!("unknown experiment `{other}`; known: {ALL_IDS:?}"),
    }?;
    let reports = std::mem::take(&mut *env.reports.borrow_mut());
    if !reports.is_empty() {
        let path = format!("{}/{id}.reports.json", env.out_dir);
        std::fs::write(&path, Json::arr(reports).to_string())
            .with_context(|| format!("write {path}"))?;
    }
    Ok(())
}
