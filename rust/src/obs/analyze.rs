//! Analysis half of the observability stack (DESIGN.md §9): turn the
//! artifacts PR 3's emit side produces — JSONL traces and
//! `gst-run-report` documents — into answers.
//!
//! Entry points, all pure functions over [`Json`] (no I/O, so the
//! CLI, tests and CI wrap them freely):
//!
//! * [`analyze_trace`] — per-step critical path, phase self-time
//!   breakdown, span-attributed worker busy/imbalance, top-k slowest
//!   steps with phase attribution, and staleness / SED-drop drift
//!   (EWMA with threshold warnings) from the `epoch_*` trace points;
//! * [`analyze_report`] — the same drift + phase shares computed from a
//!   run-report document (v1–v3 — the reader tolerates all);
//! * [`diff_reports`] — field-by-field comparison of two run reports
//!   (step p50/p95/steady-mean, phase totals, cache hit rates, worker
//!   imbalance, lock-wait totals) with a `--fail-on-regression`
//!   percentage; the CI perf-regression gate is exactly this function;
//! * [`diff_traces`] — step-by-step comparison of two JSONL traces
//!   (aligned by step *index*) that localizes a regression to the step
//!   ranges and dominant phase where it concentrates;
//! * [`trend_append`] / [`trend_analyze`] — the committed bounded ring
//!   of per-run report samples (`rust/baselines/trend_ring.json`) that
//!   makes drift visible across commits, not just against one baseline.

use std::collections::BTreeMap;

use crate::util::json::Json;
use crate::util::stats;

/// EWMA smoothing factor for the drift series (higher = more reactive).
const EWMA_ALPHA: f64 = 0.3;
/// Staleness drift warning: epoch mean > EWMA × this factor.
const STALENESS_DRIFT_FACTOR: f64 = 1.5;
/// SED drift warning: |epoch drop rate − EWMA| above this absolute gap.
const SED_DRIFT_ABS: f64 = 0.1;
/// Time-valued diff fields below this floor (ms) are skipped — relative
/// deltas on near-zero timings are pure noise.
const MIN_TIME_MS: f64 = 0.05;
/// Rate-valued diff fields below this floor are skipped likewise.
const MIN_RATE: f64 = 0.01;

/// Report schemas the readers accept (v1 predates the worker/contention
/// sections, v3 adds `contention.by_phase`; every field kept its
/// meaning across versions, so readers accept all three).
pub const REPORT_SCHEMAS: [&str; 3] =
    ["gst-run-report/v1", "gst-run-report/v2", "gst-run-report/v3"];

/// Schema of the committed trend ring (`rust/baselines/trend_ring.json`).
pub const TREND_RING_SCHEMA: &str = "gst-trend-ring/v1";
/// Default bounded ring size: appends past this rotate the oldest
/// entry out, so the committed file can never grow without bound.
pub const TREND_RING_DEFAULT_CAP: usize = 50;
/// Trailing worsening deltas that count as monotone drift (3 deltas =
/// 4 entries each strictly worse than the one before).
const TREND_MONOTONE_RUN: usize = 3;

/// In-step leaf phases, in commit order (the remaining phases — `step`,
/// `eval`, `finetune` — are not step-internal).
const LEAF_PHASES: [&str; 5] =
    ["sample", "fill", "embed_fwd", "grad", "table_commit"];

/// Validate a run-report document's schema tag; returns it on success.
pub fn check_report_schema(doc: &Json) -> Result<&str, String> {
    let schema = doc
        .get("schema")
        .and_then(|s| s.as_str())
        .ok_or("document has no `schema` key — not a gst-run-report")?;
    if REPORT_SCHEMAS.contains(&schema) {
        Ok(schema)
    } else {
        Err(format!(
            "unsupported schema `{schema}` (accepted: {})",
            REPORT_SCHEMAS.join(", ")
        ))
    }
}

/// EWMA over `vals`, seeded with the first value.
fn ewma_series(vals: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(vals.len());
    let mut e = 0.0;
    for (i, &v) in vals.iter().enumerate() {
        e = if i == 0 { v } else { EWMA_ALPHA * v + (1.0 - EWMA_ALPHA) * e };
        out.push(e);
    }
    out
}

/// Dotted-path numeric lookup (`"steps.p50_ms"`), `None` when any hop
/// is missing or non-numeric.
fn num_at(doc: &Json, path: &str) -> Option<f64> {
    let mut cur = doc;
    for key in path.split('.') {
        cur = cur.get(key)?;
    }
    cur.as_f64()
}

// -- trace analysis ------------------------------------------------------

/// Per-step aggregate assembled from the trace's span events.
#[derive(Default)]
struct StepAgg {
    /// outer `step` span duration, µs
    dur_us: f64,
    /// in-step leaf phase totals, µs
    phase_us: BTreeMap<String, f64>,
    /// span-attributed busy per worker id, µs
    worker_us: BTreeMap<i64, f64>,
}

impl StepAgg {
    fn leaf(&self, phase: &str) -> f64 {
        self.phase_us.get(phase).copied().unwrap_or(0.0)
    }

    /// Critical path through the step's plan → parallel compute →
    /// commit structure: the serial phases in full, plus the *slowest
    /// worker's* share of the parallel compute region (untagged traces
    /// fall back to the serial sum of the compute phases).
    fn critical_us(&self) -> (f64, f64, f64) {
        let sample = self.leaf("sample");
        let commit = self.leaf("table_commit");
        let compute = if self.worker_us.is_empty() {
            self.leaf("fill") + self.leaf("embed_fwd") + self.leaf("grad")
        } else {
            self.worker_us.values().fold(0.0f64, |a, &b| a.max(b))
        };
        (sample, compute, commit)
    }

    fn dominant_phase(&self) -> (&'static str, f64) {
        let mut best = ("none", 0.0f64);
        for p in LEAF_PHASES {
            let us = self.leaf(p);
            if us > best.1 {
                best = (p, us);
            }
        }
        best
    }
}

/// Everything [`parse_trace`] extracts from one JSONL trace — the
/// shared substrate of [`analyze_trace`] and [`diff_traces`].
#[derive(Default)]
struct TraceData {
    spans: usize,
    points: usize,
    /// per-phase (total µs, call count) over the whole trace
    phase_tot: BTreeMap<String, (f64, u64)>,
    /// per-step aggregates, keyed (and ordered) by step id
    steps: BTreeMap<u64, StepAgg>,
    /// span-attributed busy per worker id, µs
    worker_tot: BTreeMap<i64, f64>,
    /// (epoch, coverage, mean staleness)
    stale_epochs: Vec<(f64, f64, f64)>,
    /// (epoch, cumulative stale_total, cumulative stale_dropped)
    sed_epochs: Vec<(f64, f64, f64)>,
}

/// Parse a JSONL trace (the `--trace-out` stream). Unknown event kinds
/// are tolerated; malformed JSON lines are an error (a truncated trace
/// should be loud).
fn parse_trace(text: &str) -> Result<TraceData, String> {
    let mut t = TraceData::default();
    let spans = &mut t.spans;
    let points = &mut t.points;
    let phase_tot = &mut t.phase_tot;
    let steps = &mut t.steps;
    let worker_tot = &mut t.worker_tot;
    let stale_epochs = &mut t.stale_epochs;
    let sed_epochs = &mut t.sed_epochs;
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let ev = Json::parse(line)
            .map_err(|e| format!("trace line {}: {e}", lineno + 1))?;
        match ev.get("ev").and_then(|v| v.as_str()) {
            Some("span") => {
                *spans += 1;
                let phase = ev
                    .get("phase")
                    .and_then(|p| p.as_str())
                    .ok_or_else(|| {
                        format!("trace line {}: span without phase", lineno + 1)
                    })?
                    .to_string();
                let dur =
                    ev.get("dur_us").and_then(|d| d.as_f64()).unwrap_or(0.0);
                let step = ev
                    .get("step")
                    .and_then(|s| s.as_f64())
                    .unwrap_or(0.0) as u64;
                let worker = ev
                    .get("worker")
                    .and_then(|w| w.as_f64())
                    .map(|w| w as i64);
                let slot = phase_tot.entry(phase.clone()).or_insert((0.0, 0));
                slot.0 += dur;
                slot.1 += 1;
                if let Some(w) = worker {
                    *worker_tot.entry(w).or_insert(0.0) += dur;
                }
                // eval/finetune run outside steps; their `step` field is
                // whatever the counter last was — don't attribute them
                if phase != "eval" && phase != "finetune" {
                    let agg = steps.entry(step).or_default();
                    if phase == "step" {
                        agg.dur_us += dur;
                    } else {
                        *agg.phase_us.entry(phase).or_insert(0.0) += dur;
                        if let Some(w) = worker {
                            *agg.worker_us.entry(w).or_insert(0.0) += dur;
                        }
                    }
                }
            }
            Some("point") => {
                *points += 1;
                let name =
                    ev.get("name").and_then(|n| n.as_str()).unwrap_or("");
                let data = ev.get("data").cloned().unwrap_or(Json::Null);
                let f = |k: &str| {
                    data.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0)
                };
                match name {
                    "epoch_staleness" => stale_epochs.push((
                        f("epoch"),
                        f("coverage"),
                        f("mean"),
                    )),
                    "epoch_sed" => sed_epochs.push((
                        f("epoch"),
                        f("stale_total"),
                        f("stale_dropped"),
                    )),
                    _ => {}
                }
            }
            _ => {}
        }
    }
    Ok(t)
}

/// Analyze a JSONL trace (the `--trace-out` stream) into a
/// `gst-trace-analysis/v1` document.
pub fn analyze_trace(text: &str, top_k: usize) -> Result<Json, String> {
    let TraceData {
        spans,
        points,
        phase_tot,
        steps,
        worker_tot,
        stale_epochs,
        sed_epochs,
    } = parse_trace(text)?;

    // step wall-clock stats, in step-id order
    let durs_ms: Vec<f64> =
        steps.values().map(|a| a.dur_us / 1e3).collect();
    let step_total_ms: f64 = durs_ms.iter().sum();
    let steps_json = Json::obj(vec![
        ("count", Json::num(durs_ms.len() as f64)),
        ("total_ms", Json::num(step_total_ms)),
        ("mean_ms", Json::num(stats::mean(&durs_ms))),
        ("p50_ms", Json::num(stats::percentile(&durs_ms, 50.0))),
        ("p95_ms", Json::num(stats::percentile(&durs_ms, 95.0))),
        ("max_ms", Json::num(stats::max(&durs_ms))),
    ]);

    // per-phase totals with share of step wall-clock
    let phases_json = Json::Obj(
        phase_tot
            .iter()
            .map(|(p, &(us, calls))| {
                let ms = us / 1e3;
                let pct = if step_total_ms > 0.0 {
                    100.0 * ms / step_total_ms
                } else {
                    0.0
                };
                (
                    p.clone(),
                    Json::obj(vec![
                        ("total_ms", Json::num(ms)),
                        ("calls", Json::num(calls as f64)),
                        ("pct_of_step", Json::num(pct)),
                    ]),
                )
            })
            .collect(),
    );

    // self-time: in-step leaves vs the step wall-clock they nest inside
    // (with >1 worker the leaf sum may exceed wall-clock; clamp at 0)
    let leaf_ms: f64 = steps
        .values()
        .map(|a| LEAF_PHASES.iter().map(|p| a.leaf(p)).sum::<f64>())
        .sum::<f64>()
        / 1e3;
    let self_json = Json::obj(vec![
        ("step_ms", Json::num(step_total_ms)),
        ("leaf_ms", Json::num(leaf_ms)),
        (
            "unattributed_ms",
            Json::num((step_total_ms - leaf_ms).max(0.0)),
        ),
    ]);

    // critical path, aggregated over steps; the stall residual (step
    // wall-clock minus critical path) is clamped to zero *per step* —
    // spans that overlap (a worker busy past the commit boundary) drive
    // a step's residual negative, and summing before clamping would let
    // one overlapping step silently eat another step's genuine stall
    let (mut cp_sample, mut cp_compute, mut cp_commit) = (0.0, 0.0, 0.0);
    let mut stall_us = 0.0f64;
    let mut clamped_steps = 0u64;
    for agg in steps.values() {
        let (s, c, t) = agg.critical_us();
        cp_sample += s;
        cp_compute += c;
        cp_commit += t;
        let resid = agg.dur_us - (s + c + t);
        if resid < 0.0 {
            clamped_steps += 1;
        } else {
            stall_us += resid;
        }
    }
    let critical_ms = (cp_sample + cp_compute + cp_commit) / 1e3;
    let critical_json = Json::obj(vec![
        ("sample_ms", Json::num(cp_sample / 1e3)),
        ("compute_ms", Json::num(cp_compute / 1e3)),
        ("commit_ms", Json::num(cp_commit / 1e3)),
        ("critical_ms", Json::num(critical_ms)),
        ("stall_ms", Json::num(stall_us / 1e3)),
        ("clamped_steps", Json::num(clamped_steps as f64)),
    ]);

    // span-attributed worker busy (worker ids are dense from 0, but a
    // sparse map stays correct if a worker recorded nothing)
    let nworkers = worker_tot
        .keys()
        .next_back()
        .map(|&w| w as usize + 1)
        .unwrap_or(0);
    let busy_ms: Vec<f64> = (0..nworkers)
        .map(|w| {
            worker_tot.get(&(w as i64)).copied().unwrap_or(0.0) / 1e3
        })
        .collect();
    let workers_json = Json::obj(vec![
        ("count", Json::num(nworkers as f64)),
        ("busy_ms", Json::arr(busy_ms.iter().map(|&b| Json::num(b)))),
        ("imbalance_pct", Json::num(super::imbalance_pct(&busy_ms))),
    ]);

    // top-k slowest steps with dominant-phase attribution
    let mut ranked: Vec<(&u64, &StepAgg)> = steps.iter().collect();
    ranked.sort_by(|a, b| {
        b.1.dur_us
            .partial_cmp(&a.1.dur_us)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(b.0))
    });
    let top_json = Json::arr(ranked.iter().take(top_k).map(|(id, agg)| {
        let (phase, us) = agg.dominant_phase();
        let pct = if agg.dur_us > 0.0 {
            100.0 * us / agg.dur_us
        } else {
            0.0
        };
        Json::obj(vec![
            ("step", Json::num(**id as f64)),
            ("dur_ms", Json::num(agg.dur_us / 1e3)),
            ("dominant_phase", Json::str(phase)),
            ("dominant_pct", Json::num(pct)),
        ])
    }));

    let stale_means: Vec<f64> =
        stale_epochs.iter().map(|&(_, _, m)| m).collect();
    let staleness_json = staleness_drift(&stale_epochs, &stale_means);
    let sed_json = sed_drift(&sed_epochs);

    Ok(Json::obj(vec![
        ("schema", Json::str("gst-trace-analysis/v1")),
        (
            "events",
            Json::obj(vec![
                ("spans", Json::num(spans as f64)),
                ("points", Json::num(points as f64)),
            ]),
        ),
        ("steps", steps_json),
        ("phases", phases_json),
        ("self_time", self_json),
        ("critical_path", critical_json),
        ("workers", workers_json),
        ("top_steps", top_json),
        ("staleness", staleness_json),
        ("sed", sed_json),
    ]))
}

/// Staleness drift section shared by the trace and report analyzers:
/// per-epoch means with the EWMA baseline each was compared against,
/// plus threshold warnings.
///
/// The emitted `ewma` is the *prior* epoch's EWMA — the baseline the
/// warning check uses. Emitting the EWMA with the epoch already folded
/// in (the old behavior) damped every excursion by (1 − α) and made the
/// rendered series disagree with the warnings derived from it.
fn staleness_drift(
    epochs: &[(f64, f64, f64)],
    means: &[f64],
) -> Json {
    let ewma = ewma_series(means);
    let baseline =
        |i: usize| if i == 0 { ewma[0] } else { ewma[i - 1] };
    let mut warnings = Vec::new();
    for i in 1..means.len() {
        if baseline(i) > 1e-9
            && means[i] > baseline(i) * STALENESS_DRIFT_FACTOR
        {
            warnings.push(Json::str(&format!(
                "staleness drift at epoch {}: mean {:.2} exceeds \
                 EWMA {:.2} by more than {:.0}%",
                epochs[i].0,
                means[i],
                baseline(i),
                (STALENESS_DRIFT_FACTOR - 1.0) * 100.0
            )));
        }
    }
    Json::obj(vec![
        (
            "epochs",
            Json::arr(epochs.iter().take(ewma.len()).enumerate().map(
                |(i, &(epoch, coverage, mean))| {
                    Json::obj(vec![
                        ("epoch", Json::num(epoch)),
                        ("coverage", Json::num(coverage)),
                        ("mean", Json::num(mean)),
                        ("ewma", Json::num(baseline(i))),
                    ])
                },
            )),
        ),
        ("warnings", Json::Arr(warnings)),
    ])
}

/// SED drop-rate drift from the cumulative `epoch_sed` counters: the
/// per-epoch rate is the *delta* drop fraction, EWMA-smoothed, warning
/// when an epoch departs from the running average by more than
/// [`SED_DRIFT_ABS`] (SED draws are Bernoulli with fixed p, so a real
/// departure means the stale-slot population itself shifted).
fn sed_drift(cumulative: &[(f64, f64, f64)]) -> Json {
    let mut rates = Vec::with_capacity(cumulative.len());
    let (mut prev_t, mut prev_d) = (0.0, 0.0);
    for &(_, t, d) in cumulative {
        let (dt, dd) = (t - prev_t, d - prev_d);
        rates.push(if dt > 0.0 { dd / dt } else { 0.0 });
        (prev_t, prev_d) = (t, d);
    }
    let ewma = ewma_series(&rates);
    // like `staleness_drift`: the emitted `ewma` is the prior-epoch
    // baseline the warning compares against, not the post-fold value
    let baseline =
        |i: usize| if i == 0 { ewma[0] } else { ewma[i - 1] };
    let mut warnings = Vec::new();
    for i in 1..rates.len() {
        if (rates[i] - baseline(i)).abs() > SED_DRIFT_ABS {
            warnings.push(Json::str(&format!(
                "SED drop-rate drift at epoch {}: {:.3} vs EWMA {:.3}",
                cumulative[i].0, rates[i], baseline(i)
            )));
        }
    }
    Json::obj(vec![
        (
            "epochs",
            Json::arr(cumulative.iter().take(ewma.len()).enumerate().map(
                |(i, &(epoch, _, _))| {
                    Json::obj(vec![
                        ("epoch", Json::num(epoch)),
                        ("drop_rate", Json::num(rates[i])),
                        ("ewma", Json::num(baseline(i))),
                    ])
                },
            )),
        ),
        ("warnings", Json::Arr(warnings)),
    ])
}

// -- report analysis -----------------------------------------------------

/// Analyze a `gst-run-report` document (v1–v3) into a
/// `gst-report-analysis/v1` summary: phase shares of step wall-clock,
/// cache hit rates, staleness drift, and — when the report carries them
/// (v2+) — the worker/contention sections verbatim (v3's contention
/// includes the per-phase lock-wait split).
pub fn analyze_report(doc: &Json) -> Result<Json, String> {
    let schema = check_report_schema(doc)?.to_string();
    let step_ms = num_at(doc, "phases.step.total_ms").unwrap_or(0.0);
    let phases_json = match doc.get("phases").and_then(|p| p.as_obj()) {
        Some(m) => Json::Obj(
            m.iter()
                .map(|(name, p)| {
                    let ms =
                        num_at(p, "total_ms").unwrap_or(0.0);
                    let pct = if step_ms > 0.0 {
                        100.0 * ms / step_ms
                    } else {
                        0.0
                    };
                    (
                        name.clone(),
                        Json::obj(vec![
                            ("total_ms", Json::num(ms)),
                            (
                                "calls",
                                Json::num(
                                    num_at(p, "calls").unwrap_or(0.0),
                                ),
                            ),
                            ("pct_of_step", Json::num(pct)),
                        ]),
                    )
                })
                .collect(),
        ),
        None => Json::Null,
    };
    let epochs: Vec<(f64, f64, f64)> = doc
        .get("staleness")
        .and_then(|s| s.as_arr())
        .map(|arr| {
            arr.iter()
                .map(|e| {
                    (
                        num_at(e, "epoch").unwrap_or(0.0),
                        num_at(e, "coverage").unwrap_or(0.0),
                        num_at(e, "mean").unwrap_or(0.0),
                    )
                })
                .collect()
        })
        .unwrap_or_default();
    let means: Vec<f64> = epochs.iter().map(|&(_, _, m)| m).collect();
    let caches = Json::obj(vec![
        (
            "fill_hit_rate",
            Json::num(num_at(doc, "caches.fill.hit_rate").unwrap_or(0.0)),
        ),
        (
            "param_literal_hit_rate",
            Json::num(
                num_at(doc, "caches.param_literal.hit_rate")
                    .unwrap_or(0.0),
            ),
        ),
    ]);
    Ok(Json::obj(vec![
        ("schema", Json::str("gst-report-analysis/v1")),
        ("source_schema", Json::str(&schema)),
        (
            "steps",
            doc.get("steps").cloned().unwrap_or(Json::Null),
        ),
        ("phases", phases_json),
        ("caches", caches),
        ("staleness", staleness_drift(&epochs, &means)),
        ("sed", doc.get("sed").cloned().unwrap_or(Json::Null)),
        (
            "workers",
            doc.get("workers").cloned().unwrap_or(Json::Null),
        ),
        (
            "contention",
            doc.get("contention").cloned().unwrap_or(Json::Null),
        ),
    ]))
}

// -- report diffing (the perf-regression gate) ---------------------------

/// One compared field: `worse_when_higher` decides the regression
/// direction (time-like fields regress upward, hit rates downward).
struct DiffField {
    name: String,
    base: f64,
    cand: f64,
    worse_when_higher: bool,
    floor: f64,
}

/// Compare two run reports field-by-field. A field regresses when it
/// moved in its worse direction by more than `fail_pct` percent
/// (relative to baseline). Fields whose baseline sits under the noise
/// floor get no relative verdict (a near-zero denominator makes every
/// delta "infinite percent"), but they are *not* blind: a higher-worse
/// candidate that itself crosses the floor by more than the fail margin
/// is an absolute regression — without this fallback, a baseline of 0.0
/// could never fail no matter how large the candidate grew. Returns the
/// `gst-report-diff/v1` document; `pass` is false iff any field
/// regressed.
pub fn diff_reports(
    base: &Json,
    cand: &Json,
    fail_pct: f64,
) -> Result<Json, String> {
    check_report_schema(base)?;
    check_report_schema(cand)?;
    let mut fields: Vec<DiffField> = Vec::new();
    let mut push = |name: &str, higher_worse: bool, floor: f64| {
        if let (Some(b), Some(c)) = (num_at(base, name), num_at(cand, name))
        {
            fields.push(DiffField {
                name: name.to_string(),
                base: b,
                cand: c,
                worse_when_higher: higher_worse,
                floor,
            });
        }
    };
    for f in ["steady_mean_ms", "p50_ms", "p95_ms"] {
        push(&format!("steps.{f}"), true, MIN_TIME_MS);
    }
    // every phase present in both documents
    if let (Some(bp), Some(cp)) = (
        base.get("phases").and_then(|p| p.as_obj()),
        cand.get("phases").and_then(|p| p.as_obj()),
    ) {
        for name in bp.keys() {
            if cp.contains_key(name) {
                push(
                    &format!("phases.{name}.total_ms"),
                    true,
                    MIN_TIME_MS,
                );
            }
        }
    }
    push("caches.fill.hit_rate", false, MIN_RATE);
    push("caches.param_literal.hit_rate", false, MIN_RATE);
    // v2-only sections: compared only when both reports carry them
    push("workers.imbalance_pct", true, 1.0);
    push("contention.total_wait_ms", true, MIN_TIME_MS);
    push("contention.table_writeback_ms", true, MIN_TIME_MS);
    push("engine.marshalled_bytes", true, 1.0);

    let mut rows = Vec::with_capacity(fields.len());
    let mut regressions = Vec::new();
    for f in &fields {
        let measurable = f.base.abs() >= f.floor;
        let delta_pct = if measurable {
            100.0 * (f.cand - f.base) / f.base
        } else {
            0.0
        };
        let regression = if measurable {
            if f.worse_when_higher {
                delta_pct > fail_pct
            } else {
                delta_pct < -fail_pct
            }
        } else {
            // absolute fallback for sub-floor baselines: a higher-worse
            // candidate clearing the floor by the fail margin regressed
            // even though no relative delta exists (lower-worse fields
            // can't meaningfully regress from a near-zero base)
            f.worse_when_higher
                && f.cand > f.floor * (1.0 + fail_pct / 100.0)
        };
        if regression {
            regressions.push(f.name.clone());
        }
        rows.push(Json::obj(vec![
            ("field", Json::str(&f.name)),
            ("base", Json::num(f.base)),
            ("candidate", Json::num(f.cand)),
            ("delta_pct", Json::num(delta_pct)),
            ("measurable", Json::Bool(measurable)),
            (
                "worse_direction",
                Json::str(if f.worse_when_higher { "up" } else { "down" }),
            ),
            ("regression", Json::Bool(regression)),
        ]));
    }
    let pass = regressions.is_empty();
    Ok(Json::obj(vec![
        ("schema", Json::str("gst-report-diff/v1")),
        ("fail_on_pct", Json::num(fail_pct)),
        ("fields", Json::Arr(rows)),
        (
            "regressions",
            Json::arr(regressions.iter().map(|r| Json::str(r))),
        ),
        ("pass", Json::Bool(pass)),
    ]))
}

// -- trace diffing (regression localization) -----------------------------

/// Diff two JSONL traces step-by-step into a `gst-trace-diff/v1`
/// document, localizing where a regression concentrates.
///
/// Steps are aligned by *index* in step-id order, not by id: micro-batch
/// grouping strides step ids by the group size, so index alignment
/// compares the i-th optimizer step of each run even when the runs used
/// different groupings. A step regresses when its candidate duration
/// exceeds base by more than `slow_pct` percent (and by more than the
/// [`MIN_TIME_MS`] noise floor in absolute terms); consecutive regressed
/// indices are grouped into hotspot ranges, each attributed to the phase
/// whose self-time grew the most over the range.
///
/// This is a localization tool, not a gate — `regressed` counts are
/// informational and the CLI never fails on them (the report diff is
/// the gate).
pub fn diff_traces(
    base_text: &str,
    cand_text: &str,
    slow_pct: f64,
) -> Result<Json, String> {
    let base = parse_trace(base_text)
        .map_err(|e| format!("base trace: {e}"))?;
    let cand = parse_trace(cand_text)
        .map_err(|e| format!("candidate trace: {e}"))?;
    let b_steps: Vec<(&u64, &StepAgg)> = base.steps.iter().collect();
    let c_steps: Vec<(&u64, &StepAgg)> = cand.steps.iter().collect();
    let n = b_steps.len().min(c_steps.len());
    let floor_us = MIN_TIME_MS * 1e3;

    // per-step verdicts over the compared prefix
    let mut deltas_us = Vec::with_capacity(n);
    let mut regressed = Vec::with_capacity(n);
    for i in 0..n {
        let d = c_steps[i].1.dur_us - b_steps[i].1.dur_us;
        regressed.push(
            d > (b_steps[i].1.dur_us * slow_pct / 100.0).max(floor_us),
        );
        deltas_us.push(d);
    }
    let regressed_count = regressed.iter().filter(|&&r| r).count();

    // group consecutive regressed indices into hotspot ranges
    let mut hotspots = Vec::new();
    let mut i = 0usize;
    while i < n {
        if !regressed[i] {
            i += 1;
            continue;
        }
        let start = i;
        while i < n && regressed[i] {
            i += 1;
        }
        let end = i - 1; // inclusive
        let delta_us: f64 = deltas_us[start..=end].iter().sum();
        let mut dominant = ("none", 0.0f64);
        for p in LEAF_PHASES {
            let mut d = 0.0;
            for k in start..=end {
                d += c_steps[k].1.leaf(p) - b_steps[k].1.leaf(p);
            }
            if d > dominant.1 {
                dominant = (p, d);
            }
        }
        hotspots.push(Json::obj(vec![
            ("start_index", Json::num(start as f64)),
            ("end_index", Json::num(end as f64)),
            // base step ids name the range for humans
            ("start_step", Json::num(*b_steps[start].0 as f64)),
            ("end_step", Json::num(*b_steps[end].0 as f64)),
            ("steps", Json::num((end - start + 1) as f64)),
            ("delta_ms", Json::num(delta_us / 1e3)),
            ("dominant_phase", Json::str(dominant.0)),
            ("dominant_delta_ms", Json::num(dominant.1 / 1e3)),
        ]));
    }

    // totals and per-phase deltas over the compared prefix
    let base_ms: f64 =
        b_steps[..n].iter().map(|(_, a)| a.dur_us).sum::<f64>() / 1e3;
    let cand_ms: f64 =
        c_steps[..n].iter().map(|(_, a)| a.dur_us).sum::<f64>() / 1e3;
    let total_delta_pct = if base_ms > MIN_TIME_MS {
        100.0 * (cand_ms - base_ms) / base_ms
    } else {
        0.0
    };
    let phases_json = Json::Obj(
        LEAF_PHASES
            .iter()
            .map(|&p| {
                let b: f64 = b_steps[..n]
                    .iter()
                    .map(|(_, a)| a.leaf(p))
                    .sum::<f64>()
                    / 1e3;
                let c: f64 = c_steps[..n]
                    .iter()
                    .map(|(_, a)| a.leaf(p))
                    .sum::<f64>()
                    / 1e3;
                let pct = if b > MIN_TIME_MS {
                    100.0 * (c - b) / b
                } else {
                    0.0
                };
                (
                    p.to_string(),
                    Json::obj(vec![
                        ("base_ms", Json::num(b)),
                        ("cand_ms", Json::num(c)),
                        ("delta_ms", Json::num(c - b)),
                        ("delta_pct", Json::num(pct)),
                    ]),
                )
            })
            .collect(),
    );
    let (mut bc, mut cc) = ([0.0f64; 3], [0.0f64; 3]);
    for (_, a) in &b_steps[..n] {
        let (s, c, t) = a.critical_us();
        bc[0] += s;
        bc[1] += c;
        bc[2] += t;
    }
    for (_, a) in &c_steps[..n] {
        let (s, c, t) = a.critical_us();
        cc[0] += s;
        cc[1] += c;
        cc[2] += t;
    }
    let critical_json = Json::obj(vec![
        ("sample_delta_ms", Json::num((cc[0] - bc[0]) / 1e3)),
        ("compute_delta_ms", Json::num((cc[1] - bc[1]) / 1e3)),
        ("commit_delta_ms", Json::num((cc[2] - bc[2]) / 1e3)),
    ]);

    Ok(Json::obj(vec![
        ("schema", Json::str("gst-trace-diff/v1")),
        ("slow_step_pct", Json::num(slow_pct)),
        (
            "steps",
            Json::obj(vec![
                ("base_count", Json::num(b_steps.len() as f64)),
                ("cand_count", Json::num(c_steps.len() as f64)),
                ("compared", Json::num(n as f64)),
                ("regressed", Json::num(regressed_count as f64)),
            ]),
        ),
        (
            "totals",
            Json::obj(vec![
                ("base_ms", Json::num(base_ms)),
                ("cand_ms", Json::num(cand_ms)),
                ("delta_ms", Json::num(cand_ms - base_ms)),
                ("delta_pct", Json::num(total_delta_pct)),
            ]),
        ),
        ("critical_path", critical_json),
        ("phases", phases_json),
        ("hotspots", Json::Arr(hotspots)),
    ]))
}

// -- trend ring (drift across commits) -----------------------------------

/// Fields each ring entry samples from a run report:
/// (flat entry key, dotted report path, worse-when-higher).
const TREND_FIELDS: [(&str, &str, bool); 7] = [
    ("steady_mean_ms", "steps.steady_mean_ms", true),
    ("p50_ms", "steps.p50_ms", true),
    ("p95_ms", "steps.p95_ms", true),
    ("total_wait_ms", "contention.total_wait_ms", true),
    ("table_writeback_ms", "contention.table_writeback_ms", true),
    ("imbalance_pct", "workers.imbalance_pct", true),
    ("fill_hit_rate", "caches.fill.hit_rate", false),
];

/// A fresh empty ring document with the given capacity.
pub fn trend_new(cap: usize) -> Json {
    Json::obj(vec![
        ("schema", Json::str(TREND_RING_SCHEMA)),
        ("cap", Json::num(cap.max(1) as f64)),
        ("entries", Json::Arr(Vec::new())),
    ])
}

fn check_ring_schema(ring: &Json) -> Result<(), String> {
    let schema = ring
        .get("schema")
        .and_then(|s| s.as_str())
        .ok_or("ring has no `schema` key — not a gst-trend-ring")?;
    if schema == TREND_RING_SCHEMA {
        Ok(())
    } else {
        Err(format!(
            "unsupported ring schema `{schema}` \
             (accepted: {TREND_RING_SCHEMA})"
        ))
    }
}

/// Append one run report's sample to the ring (pure: returns the new
/// ring document), rotating the oldest entries out past `cap`. Labels
/// are caller-chosen (CI passes the commit SHA); the ring stays
/// timestamp-free so re-running the same append is deterministic.
pub fn trend_append(
    ring: &Json,
    report: &Json,
    label: &str,
    cap: usize,
) -> Result<Json, String> {
    check_ring_schema(ring)?;
    check_report_schema(report)?;
    let mut entry = vec![("label", Json::str(label))];
    for (key, path, _) in TREND_FIELDS {
        if let Some(v) = num_at(report, path) {
            entry.push((key, Json::num(v)));
        }
    }
    let mut entries: Vec<Json> = ring
        .get("entries")
        .and_then(|e| e.as_arr())
        .map(|a| a.to_vec())
        .unwrap_or_default();
    entries.push(Json::obj(entry));
    let cap = cap.max(1);
    while entries.len() > cap {
        entries.remove(0);
    }
    Ok(Json::obj(vec![
        ("schema", Json::str(TREND_RING_SCHEMA)),
        ("cap", Json::num(cap as f64)),
        ("entries", Json::Arr(entries)),
    ]))
}

/// Analyze a trend ring into a `gst-trend-analysis/v1` document:
/// per-field series with first → last deltas, plus a monotone-drift
/// warning when a field worsened strictly for the trailing
/// [`TREND_MONOTONE_RUN`]+ deltas — slow creep that no single
/// baseline diff would ever flag.
pub fn trend_analyze(ring: &Json) -> Result<Json, String> {
    check_ring_schema(ring)?;
    let empty: Vec<Json> = Vec::new();
    let entries = ring
        .get("entries")
        .and_then(|e| e.as_arr())
        .unwrap_or(&empty);
    let labels = Json::arr(entries.iter().map(|e| {
        e.get("label").cloned().unwrap_or(Json::Null)
    }));
    let mut fields: Vec<(String, Json)> = Vec::new();
    let mut warnings = Vec::new();
    for (key, _, higher_worse) in TREND_FIELDS {
        let series: Vec<f64> =
            entries.iter().filter_map(|e| num_at(e, key)).collect();
        if series.is_empty() {
            continue;
        }
        let (first, last) = (series[0], series[series.len() - 1]);
        let delta_pct = if first.abs() > 1e-12 {
            100.0 * (last - first) / first
        } else {
            0.0
        };
        // length of the strictly-worsening run ending at the tail
        let mut run = 0usize;
        for i in (1..series.len()).rev() {
            let worse = if higher_worse {
                series[i] > series[i - 1]
            } else {
                series[i] < series[i - 1]
            };
            if worse {
                run += 1;
            } else {
                break;
            }
        }
        if run >= TREND_MONOTONE_RUN {
            warnings.push(Json::str(&format!(
                "monotone drift in {key}: {} consecutive {} entries \
                 (now {last:.3})",
                run + 1,
                if higher_worse { "rising" } else { "falling" },
            )));
        }
        fields.push((
            key.to_string(),
            Json::obj(vec![
                (
                    "series",
                    Json::arr(series.iter().map(|&v| Json::num(v))),
                ),
                ("first", Json::num(first)),
                ("last", Json::num(last)),
                ("delta_pct", Json::num(delta_pct)),
                ("monotone_run", Json::num(run as f64)),
                (
                    "worse_direction",
                    Json::str(if higher_worse { "up" } else { "down" }),
                ),
            ]),
        ));
    }
    Ok(Json::obj(vec![
        ("schema", Json::str("gst-trend-analysis/v1")),
        ("entries", Json::num(entries.len() as f64)),
        ("labels", labels),
        ("fields", Json::Obj(fields.into_iter().collect())),
        ("warnings", Json::Arr(warnings)),
    ]))
}

// -- text rendering (the CLI's human-facing view) ------------------------

fn fmt_warnings(out: &mut String, section: &Json) {
    if let Some(warns) = section.get("warnings").and_then(|w| w.as_arr()) {
        for w in warns {
            if let Some(s) = w.as_str() {
                out.push_str(&format!("  warning: {s}\n"));
            }
        }
    }
}

/// Render a `gst-trace-analysis/v1` or `gst-report-analysis/v1`
/// document for the terminal.
pub fn render_analysis(a: &Json) -> String {
    let mut out = String::new();
    let schema = a.get("schema").and_then(|s| s.as_str()).unwrap_or("?");
    out.push_str(&format!("{schema}\n"));
    if let Some(steps) = a.get("steps").filter(|s| s.as_obj().is_some()) {
        let g = |k: &str| num_at(steps, k).unwrap_or(0.0);
        out.push_str(&format!(
            "steps: {}  mean {:.3} ms  p50 {:.3}  p95 {:.3}  max {:.3}\n",
            g("count") as u64,
            g("mean_ms"),
            g("p50_ms"),
            g("p95_ms"),
            g("max_ms")
        ));
    }
    if let Some(phases) = a.get("phases").and_then(|p| p.as_obj()) {
        out.push_str("phase breakdown:\n");
        let mut rows: Vec<_> = phases.iter().collect();
        rows.sort_by(|a, b| {
            num_at(b.1, "total_ms")
                .partial_cmp(&num_at(a.1, "total_ms"))
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        for (name, p) in rows {
            out.push_str(&format!(
                "  {:<14} {:>10.3} ms  {:>5.1}% of step  ({} calls)\n",
                name,
                num_at(p, "total_ms").unwrap_or(0.0),
                num_at(p, "pct_of_step").unwrap_or(0.0),
                num_at(p, "calls").unwrap_or(0.0) as u64
            ));
        }
    }
    if let Some(cp) = a.get("critical_path") {
        let g = |k: &str| num_at(cp, k).unwrap_or(0.0);
        out.push_str(&format!(
            "critical path: sample {:.3} + compute {:.3} + commit {:.3} \
             = {:.3} ms  (stall {:.3})\n",
            g("sample_ms"),
            g("compute_ms"),
            g("commit_ms"),
            g("critical_ms"),
            g("stall_ms")
        ));
        let clamped = g("clamped_steps") as u64;
        if clamped > 0 {
            out.push_str(&format!(
                "  warning: {clamped} step(s) had overlapping spans \
                 (critical path exceeded wall-clock; stall clamped to 0)\n"
            ));
        }
    }
    if let Some(w) = a.get("workers").filter(|w| w.as_obj().is_some()) {
        let busy: Vec<String> = w
            .get("busy_ms")
            .and_then(|b| b.as_arr())
            .map(|arr| {
                arr.iter()
                    .map(|v| format!("{:.2}", v.as_f64().unwrap_or(0.0)))
                    .collect()
            })
            .unwrap_or_default();
        out.push_str(&format!(
            "workers: {}  busy [{}] ms  imbalance {:.1}%\n",
            num_at(w, "count").unwrap_or(0.0) as u64,
            busy.join(", "),
            num_at(w, "imbalance_pct").unwrap_or(0.0)
        ));
    }
    if let Some(top) = a.get("top_steps").and_then(|t| t.as_arr()) {
        if !top.is_empty() {
            out.push_str("slowest steps:\n");
            for s in top {
                out.push_str(&format!(
                    "  step {:>5}  {:>9.3} ms  dominant {} ({:.1}%)\n",
                    num_at(s, "step").unwrap_or(0.0) as u64,
                    num_at(s, "dur_ms").unwrap_or(0.0),
                    s.get("dominant_phase")
                        .and_then(|p| p.as_str())
                        .unwrap_or("?"),
                    num_at(s, "dominant_pct").unwrap_or(0.0)
                ));
            }
        }
    }
    if let Some(st) = a.get("staleness").filter(|s| s.as_obj().is_some()) {
        if let Some(arr) = st.get("epochs").and_then(|e| e.as_arr()) {
            if !arr.is_empty() {
                out.push_str("staleness drift (mean / prior EWMA):\n");
                for e in arr {
                    out.push_str(&format!(
                        "  epoch {:>3}  {:.2} / {:.2}\n",
                        num_at(e, "epoch").unwrap_or(0.0) as u64,
                        num_at(e, "mean").unwrap_or(0.0),
                        num_at(e, "ewma").unwrap_or(0.0)
                    ));
                }
            }
        }
        fmt_warnings(&mut out, st);
    }
    if let Some(sed) = a.get("sed").filter(|s| s.as_obj().is_some()) {
        if let Some(arr) = sed.get("epochs").and_then(|e| e.as_arr()) {
            if !arr.is_empty() {
                out.push_str("SED drop-rate drift (rate / prior EWMA):\n");
                for e in arr {
                    out.push_str(&format!(
                        "  epoch {:>3}  {:.3} / {:.3}\n",
                        num_at(e, "epoch").unwrap_or(0.0) as u64,
                        num_at(e, "drop_rate").unwrap_or(0.0),
                        num_at(e, "ewma").unwrap_or(0.0)
                    ));
                }
            }
        }
        fmt_warnings(&mut out, sed);
    }
    out
}

/// Render a `gst-report-diff/v1` document for the terminal.
pub fn render_diff(d: &Json) -> String {
    let mut out = String::new();
    let pass = d.get("pass").and_then(|p| p.as_bool()).unwrap_or(false);
    out.push_str(&format!(
        "{:<34} {:>12} {:>12} {:>9}\n",
        "field", "base", "candidate", "delta"
    ));
    if let Some(rows) = d.get("fields").and_then(|f| f.as_arr()) {
        for r in rows {
            let mark = if r
                .get("regression")
                .and_then(|x| x.as_bool())
                .unwrap_or(false)
            {
                "  << REGRESSION"
            } else {
                ""
            };
            out.push_str(&format!(
                "{:<34} {:>12.3} {:>12.3} {:>8.1}%{}\n",
                r.get("field").and_then(|f| f.as_str()).unwrap_or("?"),
                num_at(r, "base").unwrap_or(0.0),
                num_at(r, "candidate").unwrap_or(0.0),
                num_at(r, "delta_pct").unwrap_or(0.0),
                mark
            ));
        }
    }
    out.push_str(&format!(
        "result: {} (fail threshold {:.0}%)\n",
        if pass { "PASS" } else { "FAIL" },
        num_at(d, "fail_on_pct").unwrap_or(0.0)
    ));
    out
}

/// Render a `gst-trace-diff/v1` document for the terminal.
pub fn render_trace_diff(d: &Json) -> String {
    let mut out = String::new();
    let g = |k: &str| num_at(d, k).unwrap_or(0.0);
    out.push_str(&format!(
        "{}\n",
        d.get("schema").and_then(|s| s.as_str()).unwrap_or("?")
    ));
    out.push_str(&format!(
        "steps: base {}  cand {}  compared {}  \
         ({} regressed > {:.0}%)\n",
        g("steps.base_count") as u64,
        g("steps.cand_count") as u64,
        g("steps.compared") as u64,
        g("steps.regressed") as u64,
        g("slow_step_pct")
    ));
    out.push_str(&format!(
        "total: {:.3} -> {:.3} ms  (delta {:+.3} ms, {:+.1}%)\n",
        g("totals.base_ms"),
        g("totals.cand_ms"),
        g("totals.delta_ms"),
        g("totals.delta_pct")
    ));
    out.push_str(&format!(
        "critical-path deltas: sample {:+.3}  compute {:+.3}  \
         commit {:+.3} ms\n",
        g("critical_path.sample_delta_ms"),
        g("critical_path.compute_delta_ms"),
        g("critical_path.commit_delta_ms")
    ));
    if let Some(phases) = d.get("phases").and_then(|p| p.as_obj()) {
        out.push_str("phase deltas (compared steps):\n");
        let mut rows: Vec<_> = phases.iter().collect();
        rows.sort_by(|a, b| {
            num_at(b.1, "delta_ms")
                .partial_cmp(&num_at(a.1, "delta_ms"))
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        for (name, p) in rows {
            out.push_str(&format!(
                "  {:<14} {:>9.3} -> {:>9.3} ms  ({:+.3})\n",
                name,
                num_at(p, "base_ms").unwrap_or(0.0),
                num_at(p, "cand_ms").unwrap_or(0.0),
                num_at(p, "delta_ms").unwrap_or(0.0)
            ));
        }
    }
    match d.get("hotspots").and_then(|h| h.as_arr()) {
        Some(hs) if !hs.is_empty() => {
            out.push_str("hotspots:\n");
            for h in hs {
                out.push_str(&format!(
                    "  steps {}..{} (index {}..{}): {:+.3} ms, \
                     dominant {} ({:+.3} ms)\n",
                    num_at(h, "start_step").unwrap_or(0.0) as u64,
                    num_at(h, "end_step").unwrap_or(0.0) as u64,
                    num_at(h, "start_index").unwrap_or(0.0) as u64,
                    num_at(h, "end_index").unwrap_or(0.0) as u64,
                    num_at(h, "delta_ms").unwrap_or(0.0),
                    h.get("dominant_phase")
                        .and_then(|p| p.as_str())
                        .unwrap_or("?"),
                    num_at(h, "dominant_delta_ms").unwrap_or(0.0)
                ));
            }
        }
        _ => {
            out.push_str(&format!(
                "hotspots: none (no step regressed beyond {:.0}%)\n",
                g("slow_step_pct")
            ));
        }
    }
    out
}

/// Render a `gst-trend-analysis/v1` document for the terminal. Long
/// series print only their trailing window — the ring holds up to
/// [`TREND_RING_DEFAULT_CAP`] entries but the recent shape is what a
/// human scans for.
pub fn render_trend(a: &Json) -> String {
    const TAIL: usize = 8;
    let mut out = String::new();
    out.push_str(&format!(
        "{} — {} entries\n",
        a.get("schema").and_then(|s| s.as_str()).unwrap_or("?"),
        num_at(a, "entries").unwrap_or(0.0) as u64
    ));
    if let Some(fields) = a.get("fields").and_then(|f| f.as_obj()) {
        for (name, f) in fields {
            let series: Vec<f64> = f
                .get("series")
                .and_then(|s| s.as_arr())
                .map(|arr| {
                    arr.iter().filter_map(|v| v.as_f64()).collect()
                })
                .unwrap_or_default();
            let skipped = series.len().saturating_sub(TAIL);
            let shown: Vec<String> = series[skipped..]
                .iter()
                .map(|v| format!("{v:.3}"))
                .collect();
            out.push_str(&format!(
                "  {:<20} {:>10.3} -> {:>10.3}  ({:+.1}%)  [{}{}]\n",
                name,
                num_at(f, "first").unwrap_or(0.0),
                num_at(f, "last").unwrap_or(0.0),
                num_at(f, "delta_pct").unwrap_or(0.0),
                if skipped > 0 { "… " } else { "" },
                shown.join(", ")
            ));
        }
    }
    fmt_warnings(&mut out, a);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_seeds_with_first_value() {
        let e = ewma_series(&[10.0, 10.0, 20.0]);
        assert_eq!(e[0], 10.0);
        assert_eq!(e[1], 10.0);
        assert!((e[2] - (0.3 * 20.0 + 0.7 * 10.0)).abs() < 1e-12);
        assert!(ewma_series(&[]).is_empty());
    }

    fn mini_report(steady: f64, p95: f64, fill_rate: f64) -> Json {
        Json::parse(&format!(
            r#"{{"schema":"gst-run-report/v2",
                "steps":{{"steady_mean_ms":{steady},"p50_ms":{steady},
                          "p95_ms":{p95}}},
                "phases":{{"step":{{"total_ms":10.0,"calls":4}},
                           "fill":{{"total_ms":2.0,"calls":8}}}},
                "caches":{{"fill":{{"hit_rate":{fill_rate}}},
                           "param_literal":{{"hit_rate":0.9}}}},
                "staleness":[]}}"#
        ))
        .unwrap()
    }

    #[test]
    fn identical_reports_pass_the_diff() {
        let r = mini_report(5.0, 8.0, 0.8);
        let d = diff_reports(&r, &r, 20.0).unwrap();
        assert_eq!(d.at("pass").as_bool(), Some(true));
        assert!(d.at("regressions").as_arr().unwrap().is_empty());
    }

    #[test]
    fn slower_candidate_fails_the_diff() {
        let base = mini_report(5.0, 8.0, 0.8);
        let cand = mini_report(6.5, 8.0, 0.8); // +30% steady mean + p50
        let d = diff_reports(&base, &cand, 20.0).unwrap();
        assert_eq!(d.at("pass").as_bool(), Some(false));
        let regs = d.at("regressions").as_arr().unwrap();
        assert!(regs
            .iter()
            .any(|r| r.as_str() == Some("steps.steady_mean_ms")));
    }

    #[test]
    fn hit_rate_regresses_downward() {
        let base = mini_report(5.0, 8.0, 0.8);
        let cand = mini_report(5.0, 8.0, 0.4); // hit rate halved
        let d = diff_reports(&base, &cand, 20.0).unwrap();
        assert_eq!(d.at("pass").as_bool(), Some(false));
        let regs = d.at("regressions").as_arr().unwrap();
        assert!(regs
            .iter()
            .any(|r| r.as_str() == Some("caches.fill.hit_rate")));
        // a *higher* hit rate is an improvement, never a regression
        let better = mini_report(5.0, 8.0, 1.0);
        let d = diff_reports(&base, &better, 20.0).unwrap();
        assert_eq!(d.at("pass").as_bool(), Some(true));
    }

    #[test]
    fn tiny_baselines_never_regress() {
        let base = mini_report(0.001, 0.001, 0.8);
        let cand = mini_report(0.04, 0.04, 0.8); // huge % on noise floor
        let d = diff_reports(&base, &cand, 20.0).unwrap();
        assert_eq!(d.at("pass").as_bool(), Some(true));
        // sub-floor rows are marked unmeasurable, not silently zeroed
        let rows = d.at("fields").as_arr().unwrap();
        let steady = rows
            .iter()
            .find(|r| r.at("field").as_str() == Some("steps.steady_mean_ms"))
            .unwrap();
        assert_eq!(steady.at("measurable").as_bool(), Some(false));
    }

    fn report_with_writeback(writeback: f64) -> Json {
        Json::parse(&format!(
            r#"{{"schema":"gst-run-report/v3",
                "steps":{{"steady_mean_ms":5.0,"p50_ms":5.0,"p95_ms":8.0}},
                "phases":{{"step":{{"total_ms":10.0,"calls":4}}}},
                "caches":{{"fill":{{"hit_rate":0.8}},
                           "param_literal":{{"hit_rate":0.9}}}},
                "contention":{{"total_wait_ms":0.2,
                               "table_writeback_ms":{writeback}}},
                "staleness":[]}}"#
        ))
        .unwrap()
    }

    #[test]
    fn sub_floor_baseline_still_fails_on_absolute_blowup() {
        // regression: base 0.0 has no relative delta, so before the
        // absolute fallback the candidate could grow without bound and
        // the gate would stay green
        let base = report_with_writeback(0.0);
        let cand = report_with_writeback(50.0);
        let d = diff_reports(&base, &cand, 20.0).unwrap();
        assert_eq!(d.at("pass").as_bool(), Some(false));
        let regs = d.at("regressions").as_arr().unwrap();
        assert!(regs
            .iter()
            .any(|r| r.as_str() == Some("contention.table_writeback_ms")));
        // identical sub-floor values still self-pass
        let d = diff_reports(&base, &base, 20.0).unwrap();
        assert_eq!(d.at("pass").as_bool(), Some(true));
        // a candidate inside the floor margin is still noise, not a fail
        let near = report_with_writeback(0.05);
        let d = diff_reports(&base, &near, 20.0).unwrap();
        assert_eq!(d.at("pass").as_bool(), Some(true));
    }

    #[test]
    fn diff_rejects_unknown_schemas() {
        let bad = Json::parse(r#"{"schema":"nope/v9"}"#).unwrap();
        let good = mini_report(5.0, 8.0, 0.8);
        assert!(diff_reports(&bad, &good, 20.0).is_err());
        assert!(diff_reports(&good, &bad, 20.0).is_err());
        assert!(check_report_schema(&Json::Null).is_err());
    }

    #[test]
    fn report_reader_accepts_v1_and_v2() {
        let v1 = Json::parse(
            r#"{"schema":"gst-run-report/v1",
                "steps":{"count":2},
                "phases":{"step":{"total_ms":4.0,"calls":2},
                          "fill":{"total_ms":1.0,"calls":4}},
                "caches":{"fill":{"hit_rate":0.5},
                          "param_literal":{"hit_rate":0.9}},
                "staleness":[{"epoch":1,"coverage":0.5,"mean":2.0}]}"#,
        )
        .unwrap();
        let a = analyze_report(&v1).unwrap();
        assert_eq!(
            a.at("source_schema").as_str(),
            Some("gst-run-report/v1")
        );
        // v1 has no worker/contention sections: reader nulls them
        assert_eq!(a.at("workers"), &Json::Null);
        assert_eq!(a.at("contention"), &Json::Null);
        let fill_pct =
            a.at("phases").at("fill").at("pct_of_step").as_f64().unwrap();
        assert!((fill_pct - 25.0).abs() < 1e-9);
        let v2 = mini_report(5.0, 8.0, 0.8);
        assert!(analyze_report(&v2).is_ok());
        // v3 (per-phase contention split) is accepted and passed through
        let v3 = report_with_writeback(1.0);
        let a = analyze_report(&v3).unwrap();
        assert_eq!(
            a.at("source_schema").as_str(),
            Some("gst-run-report/v3")
        );
        assert_eq!(
            a.at("contention").at("table_writeback_ms").as_f64(),
            Some(1.0)
        );
    }

    #[test]
    fn sed_drift_flags_rate_jumps() {
        // cumulative counters: epoch rates 0.5, 0.5, then 0.9
        let j = sed_drift(&[
            (1.0, 100.0, 50.0),
            (2.0, 200.0, 100.0),
            (3.0, 300.0, 190.0),
        ]);
        let warns = j.at("warnings").as_arr().unwrap();
        assert_eq!(warns.len(), 1);
        assert!(warns[0].as_str().unwrap().contains("epoch 3"));
        let epochs = j.at("epochs").as_arr().unwrap();
        assert!(
            (epochs[2].at("drop_rate").as_f64().unwrap() - 0.9).abs()
                < 1e-12
        );
        // the emitted ewma is the *prior* epoch's baseline — the value
        // the warning actually compared 0.9 against (0.5), not the
        // post-fold 0.62
        assert!(
            (epochs[2].at("ewma").as_f64().unwrap() - 0.5).abs() < 1e-12
        );
        // row 0 carries its own seed
        assert!(
            (epochs[0].at("ewma").as_f64().unwrap() - 0.5).abs() < 1e-12
        );
    }

    #[test]
    fn staleness_drift_emits_prior_ewma_baseline() {
        let epochs = [(1.0, 0.5, 2.0), (2.0, 0.8, 3.0), (3.0, 1.0, 3.0)];
        let means = [2.0, 3.0, 3.0];
        let j = staleness_drift(&epochs, &means);
        let rows = j.at("epochs").as_arr().unwrap();
        assert_eq!(rows[0].at("ewma").as_f64(), Some(2.0));
        // row 1's baseline is epoch 0's EWMA (2.0), not 0.3·3+0.7·2=2.3
        assert_eq!(rows[1].at("ewma").as_f64(), Some(2.0));
        assert!((rows[2].at("ewma").as_f64().unwrap() - 2.3).abs() < 1e-12);
        // threshold edge: mean exactly at baseline × factor must NOT warn
        // (the check is strictly greater-than)
        let epochs = [(1.0, 1.0, 2.0), (2.0, 1.0, 3.0)];
        let means = [2.0, 3.0];
        let j = staleness_drift(&epochs, &means);
        assert!(j.at("warnings").as_arr().unwrap().is_empty());
        // one epsilon past the edge warns
        let epochs = [(1.0, 1.0, 2.0), (2.0, 1.0, 3.001)];
        let means = [2.0, 3.001];
        let j = staleness_drift(&epochs, &means);
        assert_eq!(j.at("warnings").as_arr().unwrap().len(), 1);
    }

    #[test]
    fn staleness_drift_flags_mean_jumps() {
        let epochs = [(1.0, 0.5, 2.0), (2.0, 0.8, 2.1), (3.0, 1.0, 9.0)];
        let means = [2.0, 2.1, 9.0];
        let j = staleness_drift(&epochs, &means);
        let warns = j.at("warnings").as_arr().unwrap();
        assert_eq!(warns.len(), 1);
        assert!(warns[0].as_str().unwrap().contains("epoch 3"));
    }

    #[test]
    fn renderers_cover_every_section() {
        let r = mini_report(5.0, 8.0, 0.8);
        let a = analyze_report(&r).unwrap();
        let text = render_analysis(&a);
        assert!(text.contains("phase breakdown"));
        let d = diff_reports(&r, &r, 20.0).unwrap();
        let text = render_diff(&d);
        assert!(text.contains("PASS"));
    }

    /// Two-step trace: step ids stride by `stride` (micro-batch
    /// grouping), durations and commit times as given (µs).
    fn two_step_trace(stride: u64, durs: [f64; 2], commits: [f64; 2]) -> String {
        let mut out = String::new();
        for i in 0..2u64 {
            let id = i * stride;
            out.push_str(&format!(
                "{{\"ev\":\"span\",\"phase\":\"table_commit\",\
                 \"step\":{id},\"dur_us\":{}}}\n",
                commits[i as usize]
            ));
            out.push_str(&format!(
                "{{\"ev\":\"span\",\"phase\":\"step\",\
                 \"step\":{id},\"dur_us\":{}}}\n",
                durs[i as usize]
            ));
        }
        out
    }

    #[test]
    fn trace_diff_aligns_by_index_and_localizes_the_phase() {
        // base ids 0,1; candidate ids 0,4 — index alignment still pairs
        // the i-th step of each run
        let base = two_step_trace(1, [1000.0, 1000.0], [100.0, 100.0]);
        let cand = two_step_trace(4, [1010.0, 1800.0], [110.0, 900.0]);
        let d = diff_traces(&base, &cand, 20.0).unwrap();
        assert_eq!(d.at("schema").as_str(), Some("gst-trace-diff/v1"));
        assert_eq!(d.at("steps").at("compared").as_f64(), Some(2.0));
        assert_eq!(d.at("steps").at("regressed").as_f64(), Some(1.0));
        let hs = d.at("hotspots").as_arr().unwrap();
        assert_eq!(hs.len(), 1);
        assert_eq!(hs[0].at("start_index").as_f64(), Some(1.0));
        assert_eq!(hs[0].at("end_index").as_f64(), Some(1.0));
        assert_eq!(
            hs[0].at("dominant_phase").as_str(),
            Some("table_commit")
        );
        assert!((hs[0].at("delta_ms").as_f64().unwrap() - 0.8).abs() < 1e-9);
        let text = render_trace_diff(&d);
        assert!(text.contains("table_commit"));
        assert!(text.contains("hotspots:"));
    }

    #[test]
    fn trace_diff_without_regression_reports_no_hotspot() {
        let base = two_step_trace(1, [1000.0, 1000.0], [100.0, 100.0]);
        let d = diff_traces(&base, &base, 20.0).unwrap();
        assert_eq!(d.at("steps").at("regressed").as_f64(), Some(0.0));
        assert!(d.at("hotspots").as_arr().unwrap().is_empty());
        let text = render_trace_diff(&d);
        assert!(text.contains("hotspots: none"));
    }

    #[test]
    fn overlapping_spans_clamp_per_step_stall() {
        // step 0: dur 500, sample 100, worker grad 300, commit 200 →
        // critical 600 > wall-clock 500, residual −100 → clamped;
        // step 1: dur 900, sample 100, worker grad 300, commit 200 →
        // residual +300 survives intact instead of being eaten
        let trace = "\
{\"ev\":\"span\",\"phase\":\"sample\",\"step\":0,\"dur_us\":100}\n\
{\"ev\":\"span\",\"phase\":\"grad\",\"step\":0,\"worker\":0,\"dur_us\":300}\n\
{\"ev\":\"span\",\"phase\":\"table_commit\",\"step\":0,\"dur_us\":200}\n\
{\"ev\":\"span\",\"phase\":\"step\",\"step\":0,\"dur_us\":500}\n\
{\"ev\":\"span\",\"phase\":\"sample\",\"step\":1,\"dur_us\":100}\n\
{\"ev\":\"span\",\"phase\":\"grad\",\"step\":1,\"worker\":0,\"dur_us\":300}\n\
{\"ev\":\"span\",\"phase\":\"table_commit\",\"step\":1,\"dur_us\":200}\n\
{\"ev\":\"span\",\"phase\":\"step\",\"step\":1,\"dur_us\":900}\n";
        let a = analyze_trace(trace, 3).unwrap();
        let cp = a.at("critical_path");
        assert_eq!(cp.at("clamped_steps").as_f64(), Some(1.0));
        assert!((cp.at("stall_ms").as_f64().unwrap() - 0.3).abs() < 1e-9);
        let text = render_analysis(&a);
        assert!(text.contains("overlapping spans"));
    }

    #[test]
    fn trend_ring_rotates_past_cap() {
        let mut ring = trend_new(3);
        for (i, steady) in [10.0, 11.0, 12.0, 13.0].iter().enumerate() {
            let rep = mini_report(*steady, 8.0, 0.8);
            ring = trend_append(&ring, &rep, &format!("c{i}"), 3).unwrap();
        }
        let entries = ring.at("entries").as_arr().unwrap();
        assert_eq!(entries.len(), 3); // oldest (c0) rotated out
        assert_eq!(entries[0].at("label").as_str(), Some("c1"));
        assert_eq!(entries[2].at("label").as_str(), Some("c3"));
        assert_eq!(entries[2].at("steady_mean_ms").as_f64(), Some(13.0));
        // appending a non-report or into a non-ring is loud
        assert!(trend_append(&ring, &Json::Null, "x", 3).is_err());
        assert!(
            trend_append(&Json::Null, &mini_report(1.0, 2.0, 0.5), "x", 3)
                .is_err()
        );
    }

    #[test]
    fn trend_analyze_warns_on_monotone_drift() {
        let mut ring = trend_new(10);
        for (i, steady) in [10.0, 10.5, 11.0, 11.5].iter().enumerate() {
            let rep = mini_report(*steady, 8.0, 0.8);
            ring = trend_append(&ring, &rep, &format!("c{i}"), 10).unwrap();
        }
        let a = trend_analyze(&ring).unwrap();
        assert_eq!(a.at("schema").as_str(), Some("gst-trend-analysis/v1"));
        assert_eq!(a.at("entries").as_f64(), Some(4.0));
        let steady = a.at("fields").at("steady_mean_ms");
        assert_eq!(steady.at("first").as_f64(), Some(10.0));
        assert_eq!(steady.at("last").as_f64(), Some(11.5));
        assert_eq!(steady.at("monotone_run").as_f64(), Some(3.0));
        let warns = a.at("warnings").as_arr().unwrap();
        assert!(warns
            .iter()
            .any(|w| w.as_str().unwrap().contains("steady_mean_ms")));
        let text = render_trend(&a);
        assert!(text.contains("monotone drift"));
        // a flat tail kills the warning: same series with a final plateau
        let rep = mini_report(11.5, 8.0, 0.8);
        let ring = trend_append(&ring, &rep, "c4", 10).unwrap();
        let a = trend_analyze(&ring).unwrap();
        assert!(a
            .at("warnings")
            .as_arr()
            .unwrap()
            .iter()
            .all(|w| !w.as_str().unwrap().contains("steady_mean_ms")));
    }
}
