//! Stale Embedding Dropout (paper §3.4, Eq. 1).
//!
//! For a graph with J segments of which S are sampled for backprop, with
//! keep probability p, each segment's aggregation weight η is:
//!
//! ```text
//! η = p + (1-p)·J/S          for sampled (fresh) segments
//! η = 0    with prob (1-p)   for stale segments (dropped)
//! η = 1    with prob p       for stale segments (kept)
//! ```
//!
//! Theorem 4.1: this reduces the staleness bias term by a factor of p while
//! adding a dropout-style regularizer. p=1 degrades to plain GST+E; p=0
//! degrades to GST-One. The trainer folds these weights into the
//! `stale_sum`/`eta_s` inputs of the AOT `grad_step`, so the L2 graph never
//! sees p.

use crate::util::rng::Pcg64;

/// The η weights for one graph's segments at one training step.
#[derive(Clone, Debug, PartialEq)]
pub struct SedWeights {
    /// Weight of each sampled (fresh) segment.
    pub eta_fresh: f32,
    /// Weight of each stale segment (0.0 = dropped, 1.0 = kept).
    pub eta_stale: Vec<f32>,
}

/// Draw SED weights. `j` = total segments, `sampled` = indices of the S
/// segments receiving gradients, `p` = keep probability.
pub fn draw(
    j: usize,
    sampled: &[usize],
    p: f32,
    rng: &mut Pcg64,
) -> SedWeights {
    let mut eta_stale = Vec::new();
    let eta_fresh = draw_into(j, sampled, p, rng, &mut eta_stale);
    SedWeights { eta_fresh, eta_stale }
}

/// [`draw`] into a caller-owned buffer (cleared and refilled to length
/// `j`), so the steady-state plan loop draws weights without allocating.
/// Consumes the RNG in exactly [`draw`]'s order: one coin per
/// non-sampled index, ascending. Returns `eta_fresh`.
pub fn draw_into(
    j: usize,
    sampled: &[usize],
    p: f32,
    rng: &mut Pcg64,
    eta_stale: &mut Vec<f32>,
) -> f32 {
    assert!(!sampled.is_empty() && sampled.len() <= j);
    assert!((0.0..=1.0).contains(&p));
    let s = sampled.len();
    let eta_fresh = p + (1.0 - p) * (j as f32) / (s as f32);
    eta_stale.clear();
    eta_stale.resize(j, 0.0);
    for (idx, slot) in eta_stale.iter_mut().enumerate() {
        if sampled.contains(&idx) {
            *slot = 0.0; // fresh segments use eta_fresh, not this array
        } else {
            *slot = if rng.coin(p as f64) { 1.0 } else { 0.0 };
        }
    }
    eta_fresh
}

/// The no-SED (GST+E) weights: every stale segment kept with weight 1 and
/// fresh segments weight 1 — the p=1 limiting case.
pub fn keep_all(j: usize, sampled: &[usize]) -> SedWeights {
    let mut eta_stale = Vec::new();
    let eta_fresh = keep_all_into(j, sampled, &mut eta_stale);
    SedWeights { eta_fresh, eta_stale }
}

/// [`keep_all`] into a caller-owned buffer; returns `eta_fresh`.
pub fn keep_all_into(
    j: usize,
    sampled: &[usize],
    eta_stale: &mut Vec<f32>,
) -> f32 {
    eta_stale.clear();
    eta_stale.resize(j, 1.0);
    for &s in sampled {
        eta_stale[s] = 0.0;
    }
    1.0
}

/// GST-One weights: drop every stale segment (p=0 limiting case). The
/// fresh up-weight J/S makes the mean-pooled embedding an unbiased
/// magnitude estimate.
pub fn drop_all(j: usize, sampled: &[usize]) -> SedWeights {
    let mut eta_stale = Vec::new();
    let eta_fresh = drop_all_into(j, sampled, &mut eta_stale);
    SedWeights { eta_fresh, eta_stale }
}

/// [`drop_all`] into a caller-owned buffer; returns `eta_fresh`.
pub fn drop_all_into(
    j: usize,
    sampled: &[usize],
    eta_stale: &mut Vec<f32>,
) -> f32 {
    let s = sampled.len();
    eta_stale.clear();
    eta_stale.resize(j, 0.0);
    (j as f32) / (s as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{forall, zip, Gen};

    #[test]
    fn eq1_fresh_weight() {
        let mut rng = Pcg64::new(0, 0);
        let w = draw(8, &[3], 0.5, &mut rng);
        assert!((w.eta_fresh - (0.5 + 0.5 * 8.0)).abs() < 1e-6);
        assert_eq!(w.eta_stale[3], 0.0);
    }

    #[test]
    fn limiting_cases_match_paper() {
        let mut rng = Pcg64::new(1, 1);
        // p=1 -> GST+E
        let w = draw(6, &[0], 1.0, &mut rng);
        assert_eq!(w.eta_fresh, 1.0);
        assert!(w.eta_stale[1..].iter().all(|&e| e == 1.0));
        assert_eq!(w, keep_all(6, &[0]));
        // p=0 -> GST-One
        let w = draw(6, &[2], 0.0, &mut rng);
        assert_eq!(w.eta_fresh, 6.0);
        assert!(w.eta_stale.iter().all(|&e| e == 0.0));
        assert_eq!(w, drop_all(6, &[2]));
    }

    #[test]
    fn keep_rate_matches_p() {
        let mut rng = Pcg64::new(2, 2);
        let p = 0.3f32;
        let trials = 4000;
        let mut kept = 0usize;
        for _ in 0..trials {
            let w = draw(10, &[0], p, &mut rng);
            kept += w.eta_stale[1..].iter().filter(|&&e| e == 1.0).count();
        }
        let rate = kept as f64 / (trials * 9) as f64;
        assert!((rate - 0.3).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn prop_expected_total_weight_is_j() {
        // E[η_fresh·S + Σ stale η] = S(p + (1-p)J/S) + (J-S)p
        //                          = Sp + (1-p)J + Jp - Sp = J.
        // (This is what keeps the mean-pooled estimate unbiased in scale.)
        forall(
            "E[sum eta] == J",
            6,
            zip(Gen::usize(2..12), Gen::usize(1..100)),
            |&(j, pseed)| {
                let p = (pseed % 100) as f32 / 100.0;
                let mut rng = Pcg64::new(pseed as u64, 9);
                let trials = 6000;
                let mut total = 0f64;
                for _ in 0..trials {
                    let w = draw(j, &[0], p, &mut rng);
                    total += w.eta_fresh as f64
                        + w.eta_stale.iter().map(|&e| e as f64).sum::<f64>();
                }
                let mean = total / trials as f64;
                (mean - j as f64).abs() < 0.25 * (j as f64).sqrt()
            },
        );
    }

    #[test]
    fn into_variants_match_owned_and_reuse_capacity() {
        let mut a = Pcg64::new(17, 4);
        let mut b = a.clone();
        let mut buf = Vec::new();
        for (j, sampled, p) in
            [(8, vec![3], 0.5f32), (3, vec![0, 2], 0.9), (12, vec![5], 0.0)]
        {
            let w = draw(j, &sampled, p, &mut a);
            let f = draw_into(j, &sampled, p, &mut b, &mut buf);
            assert_eq!(w.eta_fresh, f);
            assert_eq!(w.eta_stale, buf);
        }
        // Both rngs consumed identical draws.
        assert_eq!(a.next_u64(), b.next_u64());
        // After warmup the buffer never reallocates for j <= capacity.
        let cap = buf.capacity();
        draw_into(4, &[1], 0.7, &mut b, &mut buf);
        assert_eq!(buf.capacity(), cap);

        let mut buf2 = Vec::new();
        assert_eq!(keep_all(6, &[0]).eta_stale, {
            keep_all_into(6, &[0], &mut buf2);
            buf2.clone()
        });
        assert_eq!(drop_all(6, &[2]).eta_fresh, {
            drop_all_into(6, &[2], &mut buf2)
        });
        assert!(buf2.iter().all(|&e| e == 0.0));
    }

    #[test]
    fn multi_segment_sampling() {
        let mut rng = Pcg64::new(3, 3);
        let w = draw(9, &[1, 4, 7], 0.5, &mut rng);
        assert!((w.eta_fresh - (0.5 + 0.5 * 3.0)).abs() < 1e-6);
        for &s in &[1usize, 4, 7] {
            assert_eq!(w.eta_stale[s], 0.0);
        }
    }

    #[test]
    #[should_panic]
    fn empty_sample_panics() {
        let mut rng = Pcg64::new(0, 0);
        draw(4, &[], 0.5, &mut rng);
    }
}
