//! Shared experiment plumbing: sizing profiles, engine/dataset caches,
//! result collection and table printing.

use crate::datasets::{MalnetDataset, MalnetSplit, TpuDataset};
use crate::runtime::Engine;
use crate::train::{MalnetTrainer, Method, RunResult, TrainConfig, TpuTrainer};
use crate::util::json::Json;
use crate::util::stats;
use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;

/// Experiment sizing. `quick` is used by the e2e test and smoke runs;
/// `full` is what EXPERIMENTS.md records.
#[derive(Clone, Copy, Debug)]
pub struct Profile {
    pub tiny_graphs: usize,
    pub large_graphs: usize,
    pub tpu_graphs: usize,
    pub tpu_configs: usize,
    pub epochs: usize,
    pub finetune_epochs: usize,
    pub tpu_epochs: usize,
    pub seeds: usize,
}

impl Profile {
    pub fn full() -> Profile {
        Profile {
            tiny_graphs: 60,
            large_graphs: 18,
            tpu_graphs: 10,
            tpu_configs: 6,
            epochs: 24,
            finetune_epochs: 8,
            tpu_epochs: 6,
            seeds: 1,
        }
    }

    pub fn quick() -> Profile {
        Profile {
            tiny_graphs: 40,
            large_graphs: 12,
            tpu_graphs: 6,
            tpu_configs: 4,
            epochs: 3,
            finetune_epochs: 1,
            tpu_epochs: 2,
            seeds: 1,
        }
    }
}

/// Root paths used by every experiment.
pub struct Env {
    pub artifacts: String,
    pub out_dir: String,
    pub profile: Profile,
    /// labeled `gst-run-report/v1` documents collected by
    /// [`run_malnet`]/[`run_tpu`] during the current experiment; the
    /// dispatcher flushes them to `<out>/<id>.reports.json`
    pub reports: RefCell<Vec<Json>>,
}

impl Env {
    pub fn new(artifacts: &str, out_dir: &str, quick: bool) -> Result<Env> {
        std::fs::create_dir_all(out_dir)
            .with_context(|| format!("mkdir {out_dir}"))?;
        Ok(Env {
            artifacts: artifacts.to_string(),
            out_dir: out_dir.to_string(),
            profile: if quick { Profile::quick() } else { Profile::full() },
            reports: RefCell::new(Vec::new()),
        })
    }

    /// Collect one labeled run report (see [`run_malnet`]/[`run_tpu`]).
    pub fn push_report(&self, label: &str, res: &RunResult) {
        self.reports.borrow_mut().push(Json::obj(vec![
            ("label", Json::str(label)),
            ("report", res.report.clone()),
        ]));
    }

    pub fn engine(&self, variant: &str) -> Result<Engine> {
        let dir = format!("{}/{variant}", self.artifacts);
        if !std::path::Path::new(&dir).is_dir() {
            return Err(anyhow!(
                "artifact variant `{variant}` not built — run `make artifacts`"
            ));
        }
        Engine::open(&dir)
    }

    pub fn malnet(&self, split: MalnetSplit, seed: u64) -> MalnetDataset {
        let count = match split {
            MalnetSplit::Tiny => self.profile.tiny_graphs,
            MalnetSplit::Large => self.profile.large_graphs,
        };
        MalnetDataset::generate(split, count, 1000 + seed)
    }

    pub fn tpu(&self, seed: u64) -> TpuDataset {
        TpuDataset::generate(
            self.profile.tpu_graphs,
            self.profile.tpu_configs,
            2000 + seed,
        )
    }

    /// Write an experiment's JSON record under `runs/`.
    pub fn save(&self, id: &str, payload: Json) -> Result<String> {
        let path = format!("{}/{id}.json", self.out_dir);
        std::fs::write(&path, payload.to_string())
            .with_context(|| format!("write {path}"))?;
        Ok(path)
    }
}

/// Accuracy ± std over seeds for one cell of a results table.
#[derive(Clone, Debug, Default)]
pub struct Cell {
    pub values: Vec<f64>,
    /// e.g. "OOM" when the run refuses to start
    pub note: Option<String>,
}

impl Cell {
    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn oom() -> Cell {
        Cell { values: vec![], note: Some("OOM".into()) }
    }

    pub fn render(&self, scale: f64) -> String {
        match (&self.note, self.values.is_empty()) {
            (Some(n), _) => n.clone(),
            (None, true) => "-".into(),
            (None, false) => format!(
                "{:.2}±{:.2}",
                scale * stats::mean(&self.values),
                scale * stats::stddev(&self.values)
            ),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("values", Json::arr(self.values.iter().map(|&v| Json::num(v)))),
            (
                "note",
                self.note
                    .as_ref()
                    .map(|n| Json::str(n))
                    .unwrap_or(Json::Null),
            ),
        ])
    }
}

/// Print an aligned table: rows × columns of rendered cells.
pub fn print_table(
    title: &str,
    col_names: &[String],
    rows: &[(String, Vec<String>)],
) {
    println!("\n=== {title} ===");
    let w0 = rows
        .iter()
        .map(|(n, _)| n.len())
        .chain([10])
        .max()
        .unwrap();
    let wc = col_names
        .iter()
        .map(|c| c.len())
        .chain(
            rows.iter().flat_map(|(_, cells)| cells.iter().map(|c| c.len())),
        )
        .max()
        .unwrap()
        .max(8);
    print!("{:<w0$}", "");
    for c in col_names {
        print!(" {c:>wc$}");
    }
    println!();
    for (name, cells) in rows {
        print!("{name:<w0$}");
        for c in cells {
            print!(" {c:>wc$}");
        }
        println!();
    }
}

/// One MalNet training run under a method, returning the RunResult
/// (errors containing "OOM" become Cell::oom upstream). Recording is
/// forced on so the run's report lands in `env.reports` under `label`.
pub fn run_malnet(
    env: &Env,
    eng: &Engine,
    data: &MalnetDataset,
    mut cfg: TrainConfig,
    label: &str,
) -> Result<RunResult> {
    cfg.obs.record = true;
    let mut tr = MalnetTrainer::new(eng, data, cfg)?;
    let res = tr.train()?;
    env.push_report(label, &res);
    Ok(res)
}

pub fn run_tpu(
    env: &Env,
    eng: &Engine,
    data: &TpuDataset,
    mut cfg: TrainConfig,
    label: &str,
) -> Result<RunResult> {
    cfg.obs.record = true;
    let mut tr = TpuTrainer::new(eng, data, cfg)?;
    let res = tr.train()?;
    env.push_report(label, &res);
    Ok(res)
}

/// Method sets used by the paper's tables.
pub fn table1_methods() -> Vec<Method> {
    Method::all().to_vec()
}

pub fn table2_methods() -> Vec<Method> {
    vec![
        Method::FullGraph,
        Method::Gst,
        Method::GstOne,
        Method::GstE,
        Method::GstEFD,
    ]
}

/// Collect cells into a json object keyed "row/col".
pub fn cells_to_json(cells: &BTreeMap<String, Cell>) -> Json {
    Json::Obj(
        cells
            .iter()
            .map(|(k, c)| (k.clone(), c.to_json()))
            .collect(),
    )
}
