//! Host-side parameter + Adam-state store, mirroring the manifest order.

use super::manifest::Manifest;
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-unique store ids, so engine-side literal caches can tell
/// distinct stores (and clones) apart without holding references.
static STORE_IDS: AtomicU64 = AtomicU64::new(0);

fn next_store_id() -> u64 {
    STORE_IDS.fetch_add(1, Ordering::Relaxed)
}

/// Model parameters plus Adam moments, all in manifest (name-sorted) order.
///
/// Each store carries a cache identity: a process-unique `id` plus a
/// `gen` counter bumped by [`ParamStore::touch`] on every mutation of
/// `values`. The engine's parameter-literal cache keys on
/// [`ParamStore::cache_key`], so literals are re-marshalled only when the
/// parameters actually changed (once per optimizer apply). Code that
/// writes `values` directly must call `touch` afterwards.
#[derive(Debug)]
pub struct ParamStore {
    pub values: Vec<Vec<f32>>,
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    /// Adam step counter (1-based at first apply).
    pub t: u32,
    id: u64,
    gen: u64,
}

impl Clone for ParamStore {
    /// Clones mutate independently, so they get a fresh cache identity.
    fn clone(&self) -> ParamStore {
        ParamStore {
            values: self.values.clone(),
            m: self.m.clone(),
            v: self.v.clone(),
            t: self.t,
            id: next_store_id(),
            gen: 0,
        }
    }
}

impl ParamStore {
    /// Load initial parameters from `init_params.bin`; moments start at 0.
    pub fn load(dir: &str, manifest: &Manifest) -> Result<ParamStore> {
        let path = format!("{dir}/init_params.bin");
        let blob = std::fs::read(&path).with_context(|| format!("read {path}"))?;
        let total: usize = manifest.params.iter().map(|p| p.elems()).sum();
        if blob.len() != total * 4 {
            bail!(
                "{path}: {} bytes, manifest wants {}",
                blob.len(),
                total * 4
            );
        }
        let mut values = Vec::with_capacity(manifest.params.len());
        let mut off = 0usize;
        for p in &manifest.params {
            let elems = p.elems();
            let mut v = vec![0f32; elems];
            for (i, c) in blob[off..off + elems * 4].chunks_exact(4).enumerate()
            {
                v[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            off += elems * 4;
            values.push(v);
        }
        let m = values.iter().map(|v| vec![0f32; v.len()]).collect();
        let v2 = values.iter().map(|v| vec![0f32; v.len()]).collect();
        Ok(ParamStore {
            values,
            m,
            v: v2,
            t: 0,
            id: next_store_id(),
            gen: 0,
        })
    }

    /// (store id, generation) — the engine literal cache's key. The id is
    /// unique per store; the generation advances on every [`touch`].
    ///
    /// [`touch`]: ParamStore::touch
    pub fn cache_key(&self) -> (u64, u64) {
        (self.id, self.gen)
    }

    /// Record a mutation of `values`, invalidating any cached parameter
    /// literals keyed on the previous generation.
    pub fn touch(&mut self) {
        self.gen = self.gen.wrapping_add(1);
    }

    pub fn num_params(&self) -> usize {
        self.values.len()
    }

    pub fn total_elems(&self) -> usize {
        self.values.iter().map(|v| v.len()).sum()
    }

    /// Select a subset (e.g. head params) as (values, m, v) triples.
    pub fn subset(&self, indices: &[usize]) -> ParamStore {
        let pick = |src: &Vec<Vec<f32>>| -> Vec<Vec<f32>> {
            indices.iter().map(|&i| src[i].clone()).collect()
        };
        ParamStore {
            values: pick(&self.values),
            m: pick(&self.m),
            v: pick(&self.v),
            t: self.t,
            id: next_store_id(),
            gen: 0,
        }
    }

    /// Write a subset back (inverse of [`ParamStore::subset`]).
    pub fn write_subset(&mut self, indices: &[usize], sub: &ParamStore) {
        assert_eq!(indices.len(), sub.values.len());
        for (k, &i) in indices.iter().enumerate() {
            self.values[i].copy_from_slice(&sub.values[k]);
            self.m[i].copy_from_slice(&sub.m[k]);
            self.v[i].copy_from_slice(&sub.v[k]);
        }
        self.touch();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::tests_support::tiny_manifest;

    #[test]
    fn load_rejects_wrong_size() {
        let dir = std::env::temp_dir().join("gst_params_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("init_params.bin"), [0u8; 12]).unwrap();
        let man = tiny_manifest();
        assert!(ParamStore::load(dir.to_str().unwrap(), &man).is_err());
    }

    #[test]
    fn load_roundtrip_and_subset() {
        let dir = std::env::temp_dir().join("gst_params_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let man = tiny_manifest(); // params: a [2,2] (4), head_b [2] (2)
        let floats: Vec<f32> = (0..6).map(|x| x as f32).collect();
        let bytes: Vec<u8> =
            floats.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(dir.join("init_params.bin"), bytes).unwrap();
        let mut ps = ParamStore::load(dir.to_str().unwrap(), &man).unwrap();
        assert_eq!(ps.values[0], vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(ps.values[1], vec![4.0, 5.0]);
        assert_eq!(ps.total_elems(), 6);
        // subset/write_subset roundtrip
        let head = man.head_indices();
        assert_eq!(head, vec![1]);
        let mut sub = ps.subset(&head);
        sub.values[0][0] = 99.0;
        ps.write_subset(&head, &sub);
        assert_eq!(ps.values[1][0], 99.0);
        assert_eq!(ps.values[0][0], 0.0);
    }

    #[test]
    fn cache_keys_track_identity_and_mutation() {
        let dir = std::env::temp_dir().join("gst_params_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let man = tiny_manifest();
        let bytes: Vec<u8> = (0..6u32)
            .flat_map(|x| (x as f32).to_le_bytes())
            .collect();
        std::fs::write(dir.join("init_params.bin"), bytes).unwrap();
        let mut ps = ParamStore::load(dir.to_str().unwrap(), &man).unwrap();
        let k0 = ps.cache_key();
        // touch bumps the generation but keeps the store id
        ps.touch();
        let k1 = ps.cache_key();
        assert_eq!(k0.0, k1.0);
        assert_ne!(k0.1, k1.1);
        // clones and subsets are distinct stores (fresh ids)
        let clone = ps.clone();
        assert_ne!(clone.cache_key().0, ps.cache_key().0);
        let sub = ps.subset(&man.head_indices());
        assert_ne!(sub.cache_key().0, ps.cache_key().0);
        // write_subset mutates values, so it must bump the generation
        let before = ps.cache_key();
        ps.write_subset(&man.head_indices(), &sub);
        assert_ne!(ps.cache_key().1, before.1);
    }
}
