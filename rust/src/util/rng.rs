//! PCG64 pseudo-random generator with splittable, named streams.
//!
//! Every source of randomness in the trainer (minibatch order, segment
//! sampling, SED keep/drop coins, dataset generation) draws from a
//! deterministic, independently-seeded stream so experiments are exactly
//! reproducible and individual components can be replayed in isolation.
//! (`Date::now`-style seeding is deliberately impossible.)

/// PCG-XSL-RR 128/64 (the reference pcg64 variant).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x1000_0000_01b3;

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Format `n` in decimal into `buf` (no allocation); returns the digits.
fn decimal_digits(mut n: u64, buf: &mut [u8; 20]) -> &[u8] {
    let mut i = buf.len();
    loop {
        i -= 1;
        buf[i] = b'0' + (n % 10) as u8;
        n /= 10;
        if n == 0 {
            break;
        }
    }
    &buf[i..]
}

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different stream ids
    /// with the same seed yield statistically independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64 | 0xda3e_39cb_94b9_5bdb) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Derive a child stream by hashing a label — lets modules carve out
    /// private streams ("sed", "batch", ...) without coordination.
    pub fn stream(&self, label: &str) -> Self {
        let h = fnv1a(FNV_OFFSET, label.as_bytes());
        Pcg64::new(self.state as u64 ^ h, h)
    }

    /// `stream(&format!("{prefix}{n}"))` without the allocation: hashes
    /// the prefix bytes then the decimal digits of `n`. FNV-1a is a
    /// byte-streaming hash, so the result is bit-identical to the
    /// formatted label (pinned by a unit test) — this keeps the per-step
    /// RNG derivation off the steady-state allocation path.
    pub fn stream_indexed(&self, prefix: &str, n: u64) -> Self {
        let mut buf = [0u8; 20];
        let digits = decimal_digits(n, &mut buf);
        let h = fnv1a(fnv1a(FNV_OFFSET, prefix.as_bytes()), digits);
        Pcg64::new(self.state as u64 ^ h, h)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased).
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "below(0)");
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Bernoulli trial with probability `p`.
    pub fn coin(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample from a (truncated) power law with exponent `alpha > 1` on
    /// `[xmin, xmax]` — used by the MalNet-like degree distributions.
    pub fn power_law(&mut self, alpha: f64, xmin: f64, xmax: f64) -> f64 {
        let u = self.f64();
        let a1 = 1.0 - alpha;
        ((xmin.powf(a1) + u * (xmax.powf(a1) - xmin.powf(a1))).powf(1.0 / a1))
            .clamp(xmin, xmax)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from `0..n` (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = Vec::with_capacity(k);
        for j in n - k..n {
            let t = self.below(j + 1);
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        chosen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_constructions() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 0);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_are_decorrelated() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn named_streams_differ() {
        let root = Pcg64::new(7, 0);
        let mut s1 = root.stream("sed");
        let mut s2 = root.stream("batch");
        assert_ne!(s1.next_u64(), s2.next_u64());
    }

    #[test]
    fn stream_indexed_matches_formatted_label() {
        let root = Pcg64::new(7, 3);
        for n in [0u64, 1, 9, 10, 99, 12345, u64::MAX] {
            let mut a = root.stream(&format!("step{n}"));
            let mut b = root.stream_indexed("step", n);
            for _ in 0..32 {
                assert_eq!(a.next_u64(), b.next_u64(), "n={n}");
            }
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::new(1, 2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = rng.below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_uniform_mean() {
        let mut rng = Pcg64::new(3, 4);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(5, 6);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(9, 0);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(11, 0);
        for _ in 0..100 {
            let mut ix = rng.sample_indices(20, 8);
            ix.sort_unstable();
            ix.dedup();
            assert_eq!(ix.len(), 8);
        }
    }

    #[test]
    fn power_law_within_bounds() {
        let mut rng = Pcg64::new(13, 0);
        for _ in 0..1000 {
            let x = rng.power_law(2.5, 1.0, 100.0);
            assert!((1.0..=100.0).contains(&x));
        }
    }
}
