//! Quickstart: train GST+EFD on a small synthetic MalNet split and print
//! the test accuracy — the smallest end-to-end use of the public API.
//!
//!     make artifacts && cargo run --release --example quickstart

use gst::datasets::{MalnetDataset, MalnetSplit};
use gst::runtime::Engine;
use gst::train::{MalnetTrainer, Method, TrainConfig};

fn main() -> anyhow::Result<()> {
    // 1. the AOT compute engine (JAX/Pallas lowered at build time)
    let eng = Engine::open("artifacts/malnet_sage_n128")?;
    println!(
        "engine: {} ({} params, batch {}, segment cap {})",
        eng.manifest.variant,
        eng.manifest.params.len(),
        eng.manifest.batch,
        eng.manifest.max_nodes
    );

    // 2. a dataset — synthetic 5-class call graphs (MalNet-Tiny analogue)
    let data = MalnetDataset::generate(MalnetSplit::Tiny, 60, 42);
    println!(
        "dataset: {} graphs, {} train / {} test",
        data.graphs.len(),
        data.train.len(),
        data.test.len()
    );

    // 3. the GST+EFD trainer: segment-sampled backprop + historical
    //    embedding table + SED + prediction-head finetuning
    let cfg = TrainConfig {
        method: Method::GstEFD,
        epochs: 10,
        finetune_epochs: 3,
        eval_every: 2,
        seed: 42,
        ..TrainConfig::default()
    };
    let mut trainer = MalnetTrainer::new(&eng, &data, cfg)?;
    println!(
        "partitioned into {} segments total; training...",
        trainer.total_segments()
    );
    let res = trainer.train()?;

    println!("\nepoch  train_acc  test_acc");
    for i in 0..res.curve.epochs.len() {
        println!(
            "{:>5}  {:>9.3}  {:>8.3}",
            res.curve.epochs[i], res.curve.train[i], res.curve.test[i]
        );
    }
    println!(
        "\nfinal: train {:.3} / test {:.3}  ({:.1} ms per step, table {:.0}% full)",
        res.train_metric,
        res.test_metric,
        res.step_ms,
        100.0 * trainer.table.coverage()
    );
    Ok(())
}
