//! Test-support code: a small quickcheck-style property-testing framework
//! (proptest is unavailable offline — DESIGN.md §3). Used by the module
//! test suites for coordinator invariants: partition correctness, SED
//! expectation laws, table consistency, padding round-trips.

pub mod prop;
