//! # gst — Graph Segment Training
//!
//! A three-layer reproduction of *"Learning Large Graph Property Prediction
//! via Graph Segment Training"* (Cao et al., NeurIPS 2023): this crate is
//! the **Layer-3 coordinator** — partitioning, segment sampling, the
//! historical embedding table, Stale Embedding Dropout, prediction-head
//! finetuning and the training loop — driving AOT-compiled JAX/Pallas
//! compute (Layers 2/1) through the PJRT C API via the [`xla`] crate.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`util`] — in-repo substrates: PCG64 RNG, JSON, CLI, thread pool
//! * [`graph`] — CSR graphs, stats, binary serialization
//! * [`datasets`] — synthetic MalNet / TpuGraphs generators
//! * [`partition`] — METIS-like, Louvain, BFS, random edge-cut; vertex-cut
//!   Random / DBH / NE (the Table 6 ablation)
//! * [`segment`] — segment extraction + padding to the AOT fixed shapes
//! * [`table`] — the historical embedding table 𝒯
//! * [`sed`] — Stale Embedding Dropout (Eq. 1)
//! * [`runtime`] — PJRT executable cache + manifest-driven marshalling
//! * [`train`] — the GST trainer: Full/GST/GST-One/+E/+EF/+ED/+EFD
//! * [`memory`] — analytic V100-16GB activation-memory model (OOM rows)
//! * [`metrics`] — accuracy, OPA, loss curves, timers
//! * [`obs`] — phase-scoped recorder, trace sinks, run reports
//! * [`exp`] — one driver per paper table/figure
//! * [`testing`] — property-testing framework used by the test suite

pub mod datasets;
pub mod exp;
pub mod graph;
pub mod memory;
pub mod metrics;
pub mod obs;
pub mod partition;
pub mod runtime;
pub mod sed;
pub mod segment;
pub mod table;
pub mod testing;
pub mod train;
pub mod util;
