//! Full-pipeline smoke: run experiment drivers end-to-end at quick sizing.
//! This is the test that proves all layers compose: synthetic data ->
//! partitioners -> padded segments -> PJRT train/eval -> metrics -> JSON.

use gst::exp::{self, common::Env};

fn artifacts_ready() -> bool {
    let d = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/malnet_sage_n128");
    std::path::Path::new(d).is_dir()
}

fn env() -> Env {
    let art = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let out = std::env::temp_dir().join("gst_e2e_runs");
    Env::new(art, out.to_str().unwrap(), true).unwrap()
}

#[test]
fn table4_and_table6_quick() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let e = env();
    exp::run("table4", &e).unwrap();
    let saved = format!("{}/table4.json", e.out_dir);
    let j = gst::util::json::Json::parse(
        &std::fs::read_to_string(&saved).unwrap(),
    )
    .unwrap();
    assert!(j.at("malnet_large").at("avg_nodes").as_f64().unwrap() > 500.0);
}

#[test]
fn fig3_quick_sed_sweep() {
    if !artifacts_ready() {
        return;
    }
    let e = env();
    exp::run("fig3", &e).unwrap();
    let j = gst::util::json::Json::parse(
        &std::fs::read_to_string(format!("{}/fig3.json", e.out_dir))
            .unwrap(),
    )
    .unwrap();
    let arr = j.as_arr().unwrap();
    assert_eq!(arr.len(), 5); // p in {0, .25, .5, .75, 1}
    for p in arr {
        for v in p.at("acc").as_arr().unwrap() {
            let acc = v.as_f64().unwrap();
            assert!((0.0..=1.0).contains(&acc));
        }
    }
}

#[test]
fn unknown_experiment_rejected() {
    let e = env();
    assert!(exp::run("table99", &e).is_err());
}
