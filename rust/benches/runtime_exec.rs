//! PJRT executable latency: the L3-visible cost of each AOT function at
//! production shapes — the §Perf L3 accounting baseline.
//!
//!     cargo bench --bench runtime_exec

#[path = "harness.rs"]
mod harness;

use gst::runtime::engine::HostTensor;
use gst::runtime::{Engine, ParamStore};
use harness::Bench;

fn main() {
    let Some(dir) = harness::artifacts("malnet_sage_n128") else {
        println!("runtime_exec: artifacts not built, skipping");
        return;
    };
    let eng = Engine::open(&dir).unwrap();
    let m = &eng.manifest;
    let ps = ParamStore::load(&dir, m).unwrap();
    let (b, n, f, h) = (m.batch, m.max_nodes, m.feat, m.hidden);
    let np = m.params.len();
    eng.warmup(&["embed_fwd", "grad_step", "apply_step", "predict"])
        .unwrap();
    println!("\nPJRT executable latency ({}):\n", m.variant);

    let params: Vec<HostTensor> =
        ps.values.iter().map(|v| HostTensor::F32(v.clone())).collect();
    let nodes = vec![0.1f32; b * n * f];
    let adj = vec![0.01f32; b * n * n];
    let mask = vec![1f32; b * n];

    let mut inputs = params.clone();
    inputs.push(HostTensor::F32(nodes.clone()));
    inputs.push(HostTensor::F32(adj.clone()));
    inputs.push(HostTensor::F32(mask.clone()));
    Bench::new("embed_fwd  (B=8 segments fwd)").iters(30).run(|| {
        eng.call("embed_fwd", &inputs).unwrap()
    });

    let mut ginputs = params.clone();
    ginputs.push(HostTensor::F32(nodes.clone()));
    ginputs.push(HostTensor::F32(adj.clone()));
    ginputs.push(HostTensor::F32(mask.clone()));
    ginputs.push(HostTensor::F32(vec![0f32; b * h]));
    ginputs.push(HostTensor::F32(vec![1f32; b]));
    ginputs.push(HostTensor::F32(vec![1f32; b]));
    ginputs.push(HostTensor::S32(vec![0i32; b]));
    let out = eng.call("grad_step", &ginputs).unwrap();
    Bench::new("grad_step  (B=8 fwd+bwd)").iters(30).run(|| {
        eng.call("grad_step", &ginputs).unwrap()
    });

    let grads: Vec<HostTensor> = out[1..1 + np].to_vec();
    let mut ainputs = params.clone();
    ainputs.extend(ps.m.iter().map(|x| HostTensor::F32(x.clone())));
    ainputs.extend(ps.v.iter().map(|x| HostTensor::F32(x.clone())));
    ainputs.extend(grads);
    ainputs.push(HostTensor::F32(vec![1.0]));
    ainputs.push(HostTensor::F32(vec![0.001]));
    Bench::new("apply_step (Adam, all params)").iters(30).run(|| {
        eng.call("apply_step", &ainputs).unwrap()
    });

    let head: Vec<usize> = m.head_indices();
    let mut pinputs: Vec<HostTensor> =
        head.iter().map(|&i| HostTensor::F32(ps.values[i].clone())).collect();
    pinputs.push(HostTensor::F32(vec![0.1f32; b * h]));
    Bench::new("predict    (head only)").iters(30).run(|| {
        eng.call("predict", &pinputs).unwrap()
    });
}
