//! Segment fill hot path: direct `fill_padded` (per-call normalization)
//! vs `PreparedSegments::fill` (precomputed weights, memcpy + scatter) vs
//! a warm `FillCache` (three memcpys). Needs no AOT artifacts — this is
//! pure host-side work. Emits BENCH_fill_ns.json (ns per fill) for the
//! CI perf trajectory.
//!
//!     cargo bench --bench fill_hotpath

#[path = "harness.rs"]
mod harness;

use gst::datasets::{MalnetDataset, MalnetSplit};
use gst::partition::Algorithm;
use gst::segment::{AdjNorm, FillCache, PreparedSegments, SegmentedGraph};
use gst::util::rng::Pcg64;

const MAX_NODES: usize = 128;
const FEAT: usize = 16;

fn main() {
    let data = MalnetDataset::generate(MalnetSplit::Large, 12, 0);
    let mut rng = Pcg64::new(0, 0x66).stream("partition");
    let segs: Vec<SegmentedGraph> = data
        .graphs
        .iter()
        .map(|g| {
            let set = Algorithm::MetisLike.partition(g, MAX_NODES, &mut rng);
            SegmentedGraph::new(g, &set)
        })
        .collect();
    let prepared: Vec<PreparedSegments> = data
        .graphs
        .iter()
        .zip(&segs)
        .map(|(g, sg)| {
            PreparedSegments::new(g, sg, AdjNorm::SymSelfLoop, MAX_NODES, FEAT)
        })
        .collect();
    let pairs: Vec<(usize, usize)> = segs
        .iter()
        .enumerate()
        .flat_map(|(g, sg)| (0..sg.num_segments()).map(move |s| (g, s)))
        .collect();
    let fills = pairs.len();
    println!(
        "\nfill hot path ({} graphs, {} fills/iter, N={}, F={}):",
        data.graphs.len(),
        fills,
        MAX_NODES,
        FEAT
    );

    let mut nodes = vec![0f32; MAX_NODES * FEAT];
    let mut adj = vec![0f32; MAX_NODES * MAX_NODES];
    let mut mask = vec![0f32; MAX_NODES];

    let bench = harness::Bench::new("direct fill_padded").warmup(2).iters(12);
    let direct_ms = bench.run(|| {
        for &(g, s) in &pairs {
            segs[g].fill_padded(
                &data.graphs[g],
                s,
                AdjNorm::SymSelfLoop,
                MAX_NODES,
                FEAT,
                None,
                &mut nodes,
                &mut adj,
                &mut mask,
            );
        }
        mask[0]
    });

    let bench = harness::Bench::new("prepared fill").warmup(2).iters(12);
    let prepared_ms = bench.run(|| {
        for &(g, s) in &pairs {
            prepared[g].fill(s, None, &mut nodes, &mut adj, &mut mask);
        }
        mask[0]
    });

    // a budget large enough to hold every block: steady state is all hits
    let cache =
        FillCache::new(256, MAX_NODES * FEAT, MAX_NODES * MAX_NODES, MAX_NODES)
            .unwrap();
    for &(g, s) in &pairs {
        prepared[g].fill(s, None, &mut nodes, &mut adj, &mut mask);
        cache.put(0, ((g as u64) << 24) | s as u64, &nodes, &adj, &mask);
    }
    let bench = harness::Bench::new("cached fill (warm)").warmup(2).iters(12);
    let cached_ms = bench.run(|| {
        for &(g, s) in &pairs {
            let hit = cache.get(
                0,
                ((g as u64) << 24) | s as u64,
                &mut nodes,
                &mut adj,
                &mut mask,
            );
            assert!(hit, "warm cache must serve every block");
        }
        mask[0]
    });

    let per_fill = |ms: f64| ms * 1e6 / fills as f64;
    let stats = cache.stats();
    println!(
        "\nper-fill: direct {:.0} ns, prepared {:.0} ns ({:.2}x), \
         cached {:.0} ns ({:.2}x); cache {} entries, {} hits",
        per_fill(direct_ms),
        per_fill(prepared_ms),
        direct_ms / prepared_ms,
        per_fill(cached_ms),
        direct_ms / cached_ms,
        cache.len(),
        stats.hits,
    );

    let series = vec![
        ("direct_fill_padded".to_string(), per_fill(direct_ms)),
        ("prepared_fill".to_string(), per_fill(prepared_ms)),
        ("cached_fill".to_string(), per_fill(cached_ms)),
    ];
    harness::emit_json_unit("fill_ns", "ns", &series, false);
}
