//! Partitioner throughput + quality across all Table 6 algorithms.
//!
//!     cargo bench --bench partitioners

#[path = "harness.rs"]
mod harness;

use gst::datasets::{MalnetDataset, MalnetSplit};
use gst::partition::Algorithm;
use gst::util::rng::Pcg64;
use harness::Bench;

fn main() {
    let data = MalnetDataset::generate(MalnetSplit::Large, 6, 1);
    let nodes: usize = data.graphs.iter().map(|g| g.num_nodes()).sum();
    println!(
        "\npartitioners: {} graphs, {} total nodes, max_size=128\n",
        data.graphs.len(),
        nodes
    );
    for alg in Algorithm::all() {
        let mut cut_total = 0usize;
        Bench::new(alg.name()).iters(5).run(|| {
            let mut rng = Pcg64::new(3, 3);
            cut_total = 0;
            for g in &data.graphs {
                let set = alg.partition(g, 128, &mut rng);
                cut_total += set.cut_cost(g);
            }
        });
        println!("{:<44} cut/replica cost = {cut_total}", "");
    }
}
