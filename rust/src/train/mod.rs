//! The GST trainer — the paper's Layer-3 coordination contribution.
//!
//! [`Method`] enumerates every training regime in Tables 1–3:
//!
//! | method    | stale segments come from        | SED      | +F finetune |
//! |-----------|---------------------------------|----------|-------------|
//! | FullGraph | (all segments get gradients)    | —        | —           |
//! | GST       | fresh forward pass (no table)   | keep all | no          |
//! | GST-One   | dropped entirely                | drop all | no          |
//! | GST+E     | historical table 𝒯              | keep all | no          |
//! | GST+EF    | historical table 𝒯              | keep all | yes         |
//! | GST+ED    | historical table 𝒯              | Eq. 1 p  | no          |
//! | GST+EFD   | historical table 𝒯              | Eq. 1 p  | yes         |
//!
//! The shared [`GstCore`] driver owns all cross-step state (parameters,
//! Adam moments, the embedding table) and drives the AOT executables;
//! [`malnet`] and [`tpu`] are thin [`GstTask`] implementations. See
//! DESIGN.md §4 for the core architecture and §6 for the method →
//! mechanism map.

pub mod core;
pub mod malnet;
pub mod ops;
pub mod tpu;

pub use self::core::{GstCore, GstTask, SlotSpec};
pub use malnet::MalnetTrainer;
pub use tpu::TpuTrainer;

use crate::partition::Algorithm;

/// Training regime (paper §5.1 "Methods").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    FullGraph,
    Gst,
    GstOne,
    GstE,
    GstEF,
    GstED,
    GstEFD,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        Some(match s.to_ascii_lowercase().as_str() {
            "full" | "full-graph" | "fullgraph" => Method::FullGraph,
            "gst" => Method::Gst,
            "gst-one" | "gstone" => Method::GstOne,
            "gst+e" | "gste" => Method::GstE,
            "gst+ef" | "gstef" => Method::GstEF,
            "gst+ed" | "gsted" => Method::GstED,
            "gst+efd" | "gstefd" => Method::GstEFD,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Method::FullGraph => "Full Graph Training",
            Method::Gst => "GST",
            Method::GstOne => "GST-One",
            Method::GstE => "GST+E",
            Method::GstEF => "GST+EF",
            Method::GstED => "GST+ED",
            Method::GstEFD => "GST+EFD",
        }
    }

    /// Does this method read stale embeddings from the historical table?
    pub fn uses_table(self) -> bool {
        matches!(
            self,
            Method::GstE | Method::GstEF | Method::GstED | Method::GstEFD
        )
    }

    /// Does this method recompute stale segments fresh each step?
    pub fn fresh_stale(self) -> bool {
        self == Method::Gst
    }

    /// Stale Embedding Dropout mode.
    pub fn sed(self, keep_p: f32) -> SedMode {
        match self {
            Method::GstOne => SedMode::DropAll,
            Method::GstED | Method::GstEFD => SedMode::Draw(keep_p),
            _ => SedMode::KeepAll,
        }
    }

    /// Does the run end with Prediction Head Finetuning?
    pub fn finetunes(self) -> bool {
        matches!(self, Method::GstEF | Method::GstEFD)
    }

    pub fn all() -> [Method; 7] {
        [
            Method::FullGraph,
            Method::Gst,
            Method::GstOne,
            Method::GstE,
            Method::GstEF,
            Method::GstED,
            Method::GstEFD,
        ]
    }
}

/// How stale-segment weights are drawn each step.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SedMode {
    KeepAll,
    DropAll,
    Draw(f32),
}

/// Trainer configuration (defaults follow the paper's App. B, scaled).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub method: Method,
    pub epochs: usize,
    /// +F finetuning epochs appended after `epochs` (paper: 100 after 600).
    pub finetune_epochs: usize,
    /// SED keep probability p (paper default 0.5).
    pub keep_p: f32,
    /// Segments sampled per graph per step (paper: S = 1).
    pub s_per_graph: usize,
    /// Worker threads computing one step's micro-batches in parallel.
    /// Pure execution knob: trained parameters are identical for any
    /// value (the conformance suite pins workers=1 ≡ workers=4).
    pub workers: usize,
    /// Micro-batches (simulated data-parallel devices) whose gradients
    /// are averaged into each optimizer step. Semantic knob: raising it
    /// scales the effective batch, exactly like adding devices to
    /// synchronous SGD. Each micro-batch reads the historical table
    /// snapshot from the start of its step (device-local staleness).
    pub micro_batches: usize,
    pub seed: u64,
    pub partition: Algorithm,
    /// Evaluate every this many epochs (curve resolution).
    pub eval_every: usize,
    /// Override the manifest's learning rate (None = manifest value).
    pub lr: Option<f32>,
    /// Byte budget (MiB) for the padded fill-block cache serving the
    /// hottest segments' (nodes, adj, mask) tensors. Like `workers`, a
    /// pure execution knob: served blocks are bit-identical to fresh
    /// fills, so trained parameters never depend on it. 0 disables.
    pub fill_cache_mb: usize,
    /// Observability sinks (report recording, JSONL trace, heartbeat).
    /// Execution-only like `workers`: parameters are bit-identical with
    /// recording on or off (pinned by `tests/gst_core.rs`).
    pub obs: crate::obs::ObsConfig,
    /// Resolve the fill-block cache through the process-wide registry
    /// (`segment::FillHandle`), so eval sweeps prewarm training fills
    /// and concurrent trainers of the same shape share one budget.
    /// Execution-only: served blocks are bit-identical either way
    /// (pinned by `tests/gst_core.rs`). `false` = private cache.
    pub shared_fill_cache: bool,
    /// Commit each micro-batch's table write-backs as sorted contiguous
    /// slot runs (one copy per run) instead of row by row. Execution-only:
    /// the batched path preserves the sequential committer's last-write-
    /// wins ordering exactly (pinned by unit + integration tests).
    /// `false` = legacy per-row commits.
    pub batched_writeback: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            method: Method::GstEFD,
            epochs: 30,
            finetune_epochs: 10,
            keep_p: 0.5,
            s_per_graph: 1,
            workers: 1,
            micro_batches: 1,
            seed: 0,
            partition: Algorithm::MetisLike,
            eval_every: 5,
            lr: None,
            fill_cache_mb: 0,
            obs: Default::default(),
            shared_fill_cache: true,
            batched_writeback: true,
        }
    }
}

/// Result of a full training run. The scalar fields are views over the
/// run's `obs::Recorder`; `report` is the complete machine-readable
/// `gst-run-report/v1` document (written out by `--report-json`).
#[derive(Clone, Debug)]
pub struct RunResult {
    pub train_metric: f64,
    pub test_metric: f64,
    /// mean wall-clock per optimization step, milliseconds, excluding
    /// the cold first epoch (Table 3)
    pub step_ms: f64,
    /// median / 95th-percentile / max step wall-clock (tail visibility
    /// the Table 3 means hide)
    pub step_p50_ms: f64,
    pub step_p95_ms: f64,
    pub step_max_ms: f64,
    pub curve: crate::metrics::Curve,
    /// total embed_fwd/grad_step/... invocations (runtime accounting)
    pub call_counts: std::collections::HashMap<String, usize>,
    /// padded fill-block cache counters (zero when `fill_cache_mb = 0`)
    pub fill_cache: crate::metrics::CacheStats,
    /// engine parameter-literal cache counters
    pub param_cache: crate::metrics::CacheStats,
    /// the full run report (`gst-run-report/v1`)
    pub report: crate::util::json::Json,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_names() {
        for m in Method::all() {
            let _ = m.name();
        }
        assert_eq!(Method::parse("GST+EFD"), Some(Method::GstEFD));
        assert_eq!(Method::parse("full"), Some(Method::FullGraph));
        assert_eq!(Method::parse("bogus"), None);
    }

    #[test]
    fn method_traits_match_paper() {
        assert!(!Method::Gst.uses_table());
        assert!(Method::Gst.fresh_stale());
        assert!(Method::GstEFD.uses_table());
        assert!(Method::GstEFD.finetunes());
        assert!(!Method::GstED.finetunes());
        assert_eq!(Method::GstOne.sed(0.5), SedMode::DropAll);
        assert_eq!(Method::GstE.sed(0.5), SedMode::KeepAll);
        assert_eq!(Method::GstEFD.sed(0.7), SedMode::Draw(0.7));
    }
}
