//! MalNet task: 5-way graph classification (Tables 1, 3; Figs 2, 3, 4, 6).
//!
//! Everything method-shaped (sampling, SED, the historical table,
//! micro-batch averaging, timing, eval cadence) lives in
//! [`GstCore`](super::core::GstCore); this module contributes only the
//! dataset mapping — table row = graph, mean pooling (1/J), class labels —
//! plus the two MalNet-only phases: the Full Graph Training baseline and
//! +F prediction-head finetuning.

use super::core::{CoreEnv, GstCore, GstTask, SlotSpec};
use super::ops::{self, BatchBufs};
use super::{Method, TrainConfig};
use crate::datasets::MalnetDataset;
use crate::metrics::{self, CacheStats, Curve};
use crate::partition::Algorithm;
use crate::runtime::{Engine, ParamStore};
use crate::segment::{FillHandle, PreparedSegments, SegmentedGraph};
use crate::util::rng::Pcg64;
use crate::util::sync::LockStats;
use anyhow::{bail, Result};

/// The MalNet trainer is the shared core driving a [`MalnetTask`]; the
/// public surface (`new` / `train` / `evaluate` / `total_segments` and the
/// `ps` / `table` / `obs` / `cfg` fields) is unchanged from the
/// pre-refactor trainer.
pub type MalnetTrainer<'a> = GstCore<'a, MalnetTask<'a>>;

impl<'a> GstCore<'a, MalnetTask<'a>> {
    /// Partition every graph and set up state. Errors with "OOM" if the
    /// method is FullGraph and any training graph exceeds the memory
    /// budget (more segments than the full-step artifact has slots — the
    /// scaled analogue of the paper's 16 GB boundary, see memory/).
    pub fn new(
        eng: &'a Engine,
        data: &'a MalnetDataset,
        cfg: TrainConfig,
    ) -> Result<MalnetTrainer<'a>> {
        let task = MalnetTask::new(eng, data, &cfg)?;
        GstCore::with_task(eng, task, cfg)
    }

    /// Test-time evaluation: fresh embeddings for every segment, mean
    /// pool, head (P_test in §3.3). Returns (accuracy, mean CE loss).
    pub fn evaluate(&self, graphs: &[usize]) -> Result<(f64, f64)> {
        self.task.eval(self.engine(), &self.ps, graphs)
    }
}

pub struct MalnetTask<'a> {
    data: &'a MalnetDataset,
    segs: Vec<SegmentedGraph>,
    /// per-graph precomputed fills (normalized edge lists + packed
    /// features) — every fill site goes through these
    prepared: Vec<PreparedSegments>,
    /// handle onto the (possibly process-shared) padded fill-block
    /// cache (`cfg.fill_cache_mb` / `cfg.shared_fill_cache`)
    fill: FillHandle,
    batch: usize,
}

impl<'a> MalnetTask<'a> {
    fn new(
        eng: &Engine,
        data: &'a MalnetDataset,
        cfg: &TrainConfig,
    ) -> Result<MalnetTask<'a>> {
        let m = &eng.manifest;
        assert_eq!(m.dataset, "malnet");
        let mut rng = Pcg64::new(cfg.seed, 0x7261).stream("partition");
        let max = m.max_nodes;
        let mut segs: Vec<SegmentedGraph> = data
            .graphs
            .iter()
            .map(|g| {
                let set = cfg.partition.partition(g, max, &mut rng);
                SegmentedGraph::new(g, &set)
            })
            .collect();
        if cfg.method == Method::FullGraph {
            // Full Graph Training backprops through ALL segments, so the
            // partition's only job is memory packing. When the configured
            // partitioner leaves slack (slivers), retry with BFS — which
            // fills segments to exactly max_nodes — before declaring OOM.
            let jmax = m.full_jmax;
            for (i, g) in data.graphs.iter().enumerate() {
                if segs[i].num_segments() > jmax {
                    let packed =
                        Algorithm::EdgeCutBfs.partition(g, max, &mut rng);
                    segs[i] = SegmentedGraph::new(g, &packed);
                }
                if segs[i].num_segments() > jmax {
                    bail!(
                        "OOM: Full Graph Training needs {} live segments \
                         for graph {i} but the memory budget holds {jmax} \
                         (paper: 16 GB V100 boundary)",
                        segs[i].num_segments()
                    );
                }
            }
        }
        // prepared fills are built from the FINAL segmentation (the
        // FullGraph repack above may have replaced entries of `segs`)
        let prepared = data
            .graphs
            .iter()
            .zip(&segs)
            .map(|(g, sg)| {
                PreparedSegments::new(g, sg, m.adj_norm, max, m.feat)
            })
            .collect();
        let fill = FillHandle::new(
            cfg.fill_cache_mb,
            cfg.shared_fill_cache,
            max * m.feat,
            max * max,
            max,
        );
        Ok(MalnetTask {
            data,
            segs,
            prepared,
            fill,
            batch: m.batch,
        })
    }

    /// The single fill path every site routes through: serve `(g, seg)`
    /// from the fill-block cache when present, else run the prepared
    /// fill (and populate the cache). Both produce output bit-identical
    /// to `fill_padded`, so the cache budget never changes training.
    fn fill_one(
        &self,
        g: usize,
        seg: usize,
        nodes: &mut [f32],
        adj: &mut [f32],
        mask: &mut [f32],
    ) {
        // graphs and segments both stay far below 2^24 at repo scale
        let key = ((g as u64) << 24) | seg as u64;
        if self.fill.get(key, nodes, adj, mask) {
            return;
        }
        self.prepared[g].fill(seg, None, nodes, adj, mask);
        self.fill.put(key, nodes, adj, mask);
    }

    /// Fresh embeddings for a list of (graph, segment) pairs, batched
    /// through `embed_fwd` (a short final chunk is padded by repeating
    /// its last entry — see [`super::core::padded_index`]).
    pub fn embed_many(
        &self,
        eng: &Engine,
        ps: &ParamStore,
        pairs: &[(usize, usize)],
    ) -> Result<Vec<Vec<f32>>> {
        let m = &eng.manifest;
        let (b, n, f, td) = (m.batch, m.max_nodes, m.feat, m.table_dim);
        let mut out = Vec::with_capacity(pairs.len());
        let mut nodes = vec![0f32; b * n * f];
        let mut adj = vec![0f32; b * n * n];
        let mut mask = vec![0f32; b * n];
        for chunk in pairs.chunks(b) {
            for slot in 0..b {
                let (g, s) = chunk[super::core::padded_index(slot, chunk.len())];
                self.fill_one(
                    g, s,
                    &mut nodes[slot * n * f..(slot + 1) * n * f],
                    &mut adj[slot * n * n..(slot + 1) * n * n],
                    &mut mask[slot * n..(slot + 1) * n],
                );
            }
            let h = ops::embed_fwd(eng, ps, &nodes, &adj, &mask)?;
            for slot in 0..chunk.len() {
                out.push(h[slot * td..(slot + 1) * td].to_vec());
            }
        }
        Ok(out)
    }

    /// (accuracy, mean CE loss) over `graphs` with the current parameters.
    pub fn eval(
        &self,
        eng: &Engine,
        ps: &ParamStore,
        graphs: &[usize],
    ) -> Result<(f64, f64)> {
        let m = &eng.manifest;
        let (b, h, td) = (m.batch, m.hidden, m.table_dim);
        assert_eq!(h, td);
        // graph embeddings
        let mut h_graphs = Vec::with_capacity(graphs.len());
        for &g in graphs {
            let pairs: Vec<(usize, usize)> = (0..self.segs[g].num_segments())
                .map(|s| (g, s))
                .collect();
            let embs = self.embed_many(eng, ps, &pairs)?;
            let mut agg = vec![0f32; td];
            for e in &embs {
                for d in 0..td {
                    agg[d] += e[d];
                }
            }
            for d in agg.iter_mut() {
                *d /= pairs.len() as f32;
            }
            h_graphs.push(agg);
        }
        // head in batches
        let head_idx = m.head_indices();
        let mut logits = Vec::with_capacity(graphs.len());
        for chunk in h_graphs.chunks(b) {
            let mut packed = vec![0f32; b * h];
            for (slot, hg) in chunk.iter().enumerate() {
                packed[slot * h..(slot + 1) * h].copy_from_slice(hg);
            }
            let lg = ops::predict(eng, ps, &head_idx, &packed)?;
            let c = m.classes;
            for slot in 0..chunk.len() {
                logits.push(lg[slot * c..(slot + 1) * c].to_vec());
            }
        }
        let labels: Vec<u8> =
            graphs.iter().map(|&g| self.data.labels[g]).collect();
        Ok((
            metrics::accuracy(&logits, &labels),
            metrics::cross_entropy(&logits, &labels),
        ))
    }

    /// Full Graph Training step over ONE graph (all segments live).
    fn full_step_one(
        &self,
        eng: &Engine,
        ps: &ParamStore,
        g: usize,
    ) -> Result<ops::StepOut> {
        let m = &eng.manifest;
        let (jm, n, f) = (m.full_jmax, m.max_nodes, m.feat);
        let j = self.segs[g].num_segments();
        assert!(j <= jm, "checked at construction");
        let mut nodes = vec![0f32; jm * n * f];
        let mut adj = vec![0f32; jm * n * n];
        let mut mask = vec![0f32; jm * n];
        let mut seg_mask = vec![0f32; jm];
        for s in 0..j {
            self.fill_one(
                g, s,
                &mut nodes[s * n * f..(s + 1) * n * f],
                &mut adj[s * n * n..(s + 1) * n * n],
                &mut mask[s * n..(s + 1) * n],
            );
            seg_mask[s] = 1.0;
        }
        ops::full_step(
            eng, ps, &nodes, &adj, &mask, &seg_mask,
            self.data.labels[g] as i32,
        )
    }
}

impl GstTask for MalnetTask<'_> {
    type StepCtx = Vec<usize>;

    fn dataset(&self) -> &'static str {
        "malnet"
    }

    fn seed_tag(&self) -> u64 {
        0x7261
    }

    fn warmup_fns(&self, method: Method) -> Vec<&'static str> {
        let mut fns = vec!["grad_step", "apply_step", "embed_fwd", "predict"];
        if method == Method::FullGraph {
            fns.push("full_step");
        }
        if method.finetunes() {
            fns.extend(["head_grad_step", "head_apply_step"]);
        }
        fns
    }

    fn table_rows(&self) -> Vec<usize> {
        self.segs.iter().map(|s| s.num_segments()).collect()
    }

    fn train_items(&self) -> &[usize] {
        &self.data.train
    }

    fn plan_epoch(&self, order: &[usize]) -> Vec<Vec<usize>> {
        order
            .chunks(self.batch)
            // drop_last, standard minibatch SGD
            .filter(|c| c.len() == self.batch)
            .map(|c| c.to_vec())
            .collect()
    }

    fn begin_step(
        &mut self,
        unit: &[usize],
        _rng: &mut Pcg64,
        slots: &mut Vec<SlotSpec>,
    ) -> Vec<usize> {
        slots.extend(unit.iter().map(|&g| {
            let j = self.segs[g].num_segments();
            SlotSpec { row: g, num_segments: j, invj: 1.0 / j as f32 }
        }));
        unit.to_vec()
    }

    fn bind_fill_generation(&mut self, gen: u64) {
        self.fill.bind_generation(gen);
    }

    fn fill_loss(&self, ctx: &Vec<usize>, bufs: &mut BatchBufs) {
        for (slot, &g) in ctx.iter().enumerate() {
            bufs.labels[slot] = self.data.labels[g] as i32;
        }
    }

    fn fill_slot(
        &self,
        ctx: &Vec<usize>,
        slot: usize,
        seg: usize,
        nodes: &mut [f32],
        adj: &mut [f32],
        mask: &mut [f32],
    ) {
        let g = ctx[slot];
        self.fill_one(g, seg, nodes, adj, mask);
    }

    fn eval_metric(
        &self,
        eng: &Engine,
        ps: &ParamStore,
        items: &[usize],
    ) -> Result<f64> {
        self.eval(eng, ps, items).map(|(acc, _ce)| acc)
    }

    fn eval_train_subset(&self) -> Vec<usize> {
        self.data.train.iter().take(40).copied().collect()
    }

    fn test_items(&self) -> &[usize] {
        &self.data.test
    }

    fn total_segments(&self) -> usize {
        self.segs.iter().map(|s| s.num_segments()).sum()
    }

    fn fill_cache_stats(&self) -> CacheStats {
        self.fill.stats()
    }

    fn prepared_bytes(&self) -> usize {
        self.prepared.iter().map(|p| p.bytes()).sum()
    }

    fn fill_cache_bytes(&self) -> usize {
        self.fill.bytes()
    }

    fn contention(&self) -> Vec<(String, LockStats)> {
        self.fill.contention()
    }

    // -- Full Graph Training baseline ---------------------------------------

    fn full_graph_epoch(&mut self, env: &mut CoreEnv<'_>) -> Result<()> {
        let b = env.eng.manifest.batch;
        let mut order = self.data.train.clone();
        let mut rng = env.rng.stream_indexed("full", *env.step as u64);
        rng.shuffle(&mut order);
        for chunk in order.chunks(b) {
            if chunk.len() < b {
                break;
            }
            env.obs.step_start();
            for &g in chunk {
                let out = self.full_step_one(env.eng, env.ps, g)?;
                env.accum.add(&out.grads);
            }
            let lr = env.lr();
            let avg = env.accum.mean();
            ops::apply(env.eng, env.ps, avg, lr)?;
            env.obs.step_stop();
            *env.step += 1;
        }
        Ok(())
    }

    // -- Prediction Head Finetuning (+F, Alg. 2 lines 11-18) ----------------

    fn finetune(
        &mut self,
        env: &mut CoreEnv<'_>,
        curve: &mut Curve,
        eval_train: &[usize],
    ) -> Result<()> {
        let m = &env.eng.manifest;
        let (b, h) = (m.batch, m.hidden);
        // 1. refresh every table row with the current backbone F
        let mut pairs = Vec::new();
        for &g in &self.data.train {
            for s in 0..self.segs[g].num_segments() {
                pairs.push((g, s));
            }
        }
        let embs = self.embed_many(env.eng, env.ps, &pairs)?;
        for ((g, s), e) in pairs.iter().zip(&embs) {
            env.table.put(*g, *s, e, *env.step);
        }
        // 2. finetune only F' on up-to-date mean-pooled embeddings, with a
        //    fresh Adam state (the backbone stays frozen)
        let head_idx = m.head_indices();
        let mut head = env.ps.subset(&head_idx);
        head.t = 0;
        for x in head.m.iter_mut().chain(head.v.iter_mut()) {
            x.fill(0.0);
        }
        let mut rng = env.rng.stream("finetune");
        for ft_epoch in 0..env.cfg.finetune_epochs {
            let mut order = self.data.train.clone();
            rng.shuffle(&mut order);
            for chunk in order.chunks(b) {
                if chunk.len() < b {
                    break;
                }
                let mut hg = vec![0f32; b * h];
                let mut labels = vec![0i32; b];
                for (slot, &g) in chunk.iter().enumerate() {
                    let j = self.segs[g].num_segments();
                    for s in 0..j {
                        let e = env.table.get(g, s).expect("refreshed");
                        for d in 0..h {
                            hg[slot * h + d] += e[d] / j as f32;
                        }
                    }
                    labels[slot] = self.data.labels[g] as i32;
                }
                let (_loss, grads) =
                    ops::head_grad_step(env.eng, &head, &hg, &labels)?;
                ops::apply_named(
                    env.eng, "head_apply_step", &mut head, &grads,
                    m.head_lr,
                )?;
                *env.step += 1;
            }
            // make the updated head visible to eval()
            env.ps.write_subset(&head_idx, &head);
            let (tr, _) = self.eval(env.eng, env.ps, eval_train)?;
            let (te, _) = self.eval(env.eng, env.ps, &self.data.test)?;
            curve.push(env.cfg.epochs + ft_epoch + 1, tr, te);
        }
        Ok(())
    }
}
