//! Compressed-sparse-row graph with node features.

/// Undirected graph in CSR form. Each undirected edge {u, v} is stored
/// twice (u→v and v→u); `num_edges` counts undirected edges once.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    /// Offsets into `adj`; length `n + 1`.
    pub offsets: Vec<u32>,
    /// Concatenated neighbor lists (sorted within each node).
    pub adj: Vec<u32>,
    /// Row-major `n × feat_dim` node features.
    pub feats: Vec<f32>,
    pub feat_dim: usize,
}

impl Csr {
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Undirected edge count.
    pub fn num_edges(&self) -> usize {
        self.adj.len() / 2
    }

    pub fn degree(&self, v: usize) -> usize {
        (self.offsets[v + 1] - self.offsets[v]) as usize
    }

    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.adj[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }

    pub fn feat(&self, v: usize) -> &[f32] {
        &self.feats[v * self.feat_dim..(v + 1) * self.feat_dim]
    }

    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.neighbors(u).binary_search(&(v as u32)).is_ok()
    }

    /// All undirected edges (u < v).
    pub fn edges(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.num_edges());
        for u in 0..self.num_nodes() {
            for &v in self.neighbors(u) {
                if (u as u32) < v {
                    out.push((u as u32, v));
                }
            }
        }
        out
    }

    /// Induced subgraph over `nodes` (order preserved). Returns the graph
    /// plus the mapping from new index -> original index.
    pub fn induced(&self, nodes: &[u32]) -> (Csr, Vec<u32>) {
        let mut rank = vec![u32::MAX; self.num_nodes()];
        for (new, &old) in nodes.iter().enumerate() {
            rank[old as usize] = new as u32;
        }
        let mut b = GraphBuilder::new(nodes.len(), self.feat_dim);
        for (new, &old) in nodes.iter().enumerate() {
            b.set_feat(new, self.feat(old as usize));
            for &w in self.neighbors(old as usize) {
                let rw = rank[w as usize];
                if rw != u32::MAX && (new as u32) < rw {
                    b.add_edge(new, rw as usize);
                }
            }
        }
        (b.build(), nodes.to_vec())
    }

    /// Connected components (BFS), as a component id per node.
    pub fn components(&self) -> Vec<u32> {
        let n = self.num_nodes();
        let mut comp = vec![u32::MAX; n];
        let mut next = 0u32;
        let mut queue = std::collections::VecDeque::new();
        for start in 0..n {
            if comp[start] != u32::MAX {
                continue;
            }
            comp[start] = next;
            queue.push_back(start as u32);
            while let Some(u) = queue.pop_front() {
                for &v in self.neighbors(u as usize) {
                    if comp[v as usize] == u32::MAX {
                        comp[v as usize] = next;
                        queue.push_back(v);
                    }
                }
            }
            next += 1;
        }
        comp
    }
}

/// Incremental builder: collect undirected edges, then `build()` the CSR.
#[derive(Clone, Debug)]
pub struct GraphBuilder {
    n: usize,
    feat_dim: usize,
    edges: Vec<(u32, u32)>,
    feats: Vec<f32>,
}

impl GraphBuilder {
    pub fn new(n: usize, feat_dim: usize) -> Self {
        GraphBuilder {
            n,
            feat_dim,
            edges: Vec::new(),
            feats: vec![0.0; n * feat_dim],
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.n
    }

    /// Add an undirected edge; self-loops and duplicates are dropped at
    /// build time.
    pub fn add_edge(&mut self, u: usize, v: usize) {
        debug_assert!(u < self.n && v < self.n, "edge ({u},{v}) out of range");
        if u != v {
            self.edges.push((u.min(v) as u32, u.max(v) as u32));
        }
    }

    pub fn set_feat(&mut self, v: usize, feat: &[f32]) {
        assert_eq!(feat.len(), self.feat_dim);
        self.feats[v * self.feat_dim..(v + 1) * self.feat_dim]
            .copy_from_slice(feat);
    }

    pub fn build(mut self) -> Csr {
        self.edges.sort_unstable();
        self.edges.dedup();
        let mut deg = vec![0u32; self.n];
        for &(u, v) in &self.edges {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut offsets = Vec::with_capacity(self.n + 1);
        offsets.push(0u32);
        for d in &deg {
            offsets.push(offsets.last().unwrap() + d);
        }
        let mut cursor: Vec<u32> = offsets[..self.n].to_vec();
        let mut adj = vec![0u32; self.edges.len() * 2];
        for &(u, v) in &self.edges {
            adj[cursor[u as usize] as usize] = v;
            cursor[u as usize] += 1;
            adj[cursor[v as usize] as usize] = u;
            cursor[v as usize] += 1;
        }
        // sort each neighbor list for binary-search lookups
        for v in 0..self.n {
            adj[offsets[v] as usize..offsets[v + 1] as usize].sort_unstable();
        }
        Csr { offsets, adj, feats: self.feats, feat_dim: self.feat_dim }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle_plus_isolate() -> Csr {
        // 0-1-2 triangle, 3 isolated
        let mut b = GraphBuilder::new(4, 2);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(2, 0);
        b.set_feat(3, &[1.0, 2.0]);
        b.build()
    }

    #[test]
    fn basic_topology() {
        let g = triangle_plus_isolate();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(3), 0);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert!(g.has_edge(0, 2));
        assert!(!g.has_edge(0, 3));
    }

    #[test]
    fn duplicates_and_self_loops_dropped() {
        let mut b = GraphBuilder::new(3, 0);
        b.add_edge(0, 1);
        b.add_edge(1, 0);
        b.add_edge(0, 0);
        let g = b.build();
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.degree(0), 1);
    }

    #[test]
    fn features_stored() {
        let g = triangle_plus_isolate();
        assert_eq!(g.feat(3), &[1.0, 2.0]);
        assert_eq!(g.feat(0), &[0.0, 0.0]);
    }

    #[test]
    fn edges_listing() {
        let g = triangle_plus_isolate();
        assert_eq!(g.edges(), vec![(0, 1), (0, 2), (1, 2)]);
    }

    #[test]
    fn induced_subgraph() {
        let g = triangle_plus_isolate();
        let (sub, map) = g.induced(&[1, 2, 3]);
        assert_eq!(sub.num_nodes(), 3);
        assert_eq!(sub.num_edges(), 1); // only 1-2 survives
        assert!(sub.has_edge(0, 1));
        assert_eq!(map, vec![1, 2, 3]);
        assert_eq!(sub.feat(2), &[1.0, 2.0]);
    }

    #[test]
    fn components_split() {
        let g = triangle_plus_isolate();
        let comp = g.components();
        assert_eq!(comp[0], comp[1]);
        assert_eq!(comp[1], comp[2]);
        assert_ne!(comp[0], comp[3]);
    }
}
