//! Data-parallel scaling driver (beyond the paper): step time vs worker
//! threads at a fixed micro-batch count, plus the worker-invariance check
//! — the refactor's observable guarantees in one report.
//!
//! `workers` is a pure execution knob in the refactored core, so the test
//! metric must be bit-identical across the sweep while `step_ms` drops as
//! threads are added; this driver asserts the former and records the
//! latter (the perf trajectory CI tracks via BENCH_step_ms.json).

use super::common::*;
use crate::datasets::malnet::MalnetSplit;
use crate::train::{Method, TrainConfig};
use crate::util::json::Json;
use anyhow::{ensure, Result};

const MICRO_BATCHES: usize = 4;
const WORKER_SWEEP: [usize; 3] = [1, 2, 4];

pub fn scaling(env: &Env) -> Result<()> {
    let eng = env.engine("malnet_sage_n128")?;
    let data = env.malnet(MalnetSplit::Tiny, 0);
    let mut rows = Vec::new();
    let mut metrics = Vec::new();
    for &workers in &WORKER_SWEEP {
        let cfg = TrainConfig {
            method: Method::GstED,
            epochs: 4.min(env.profile.epochs.max(2)),
            finetune_epochs: 0,
            eval_every: 99,
            seed: 0,
            workers,
            micro_batches: MICRO_BATCHES,
            ..TrainConfig::default()
        };
        let label = format!("workers{workers}");
        let res = run_malnet(env, &eng, &data, cfg, &label)?;
        metrics.push(res.test_metric);
        rows.push((workers, res.step_ms, res.test_metric));
    }
    ensure!(
        metrics.iter().all(|&m| m == metrics[0]),
        "worker-count invariance violated: {metrics:?}"
    );
    println!(
        "\n=== Scaling: {MICRO_BATCHES} micro-batches over worker \
         threads (GST+ED, SAGE, malnet-tiny) ==="
    );
    println!("{:>8} {:>12} {:>10}", "workers", "ms/step", "test acc");
    for (w, ms, acc) in &rows {
        println!("{w:>8} {ms:>12.2} {acc:>10.4}");
    }
    println!("(test acc identical across the sweep: threads are an \
              execution knob, micro-batches the semantic one)");
    let path = env.save(
        "scaling",
        Json::obj(vec![
            ("micro_batches", Json::num(MICRO_BATCHES as f64)),
            (
                "sweep",
                Json::arr(rows.iter().map(|(w, ms, acc)| {
                    Json::obj(vec![
                        ("workers", Json::num(*w as f64)),
                        ("step_ms", Json::num(*ms)),
                        ("test_metric", Json::num(*acc)),
                    ])
                })),
            ),
        ]),
    )?;
    println!("saved {path}");
    Ok(())
}
