"""L2: GST model definitions in JAX, calling the L1 Pallas kernels.

This module defines, per variant (see config.VariantConfig):

  backbones   gcn / sage / gps-lite  — per-node encoders over a padded
              segment batch (B, N, F) with a dense normalized adjacency
              (B, N, N) and a node mask (B, N)
  heads       malnet: 2-layer MLP -> 5-way logits (this is F', the paper's
              prediction head that +F finetunes);
              tpu: per-node runtime head *inside* F, summed per segment —
              the paper's section 5.3 design where F' is just summation
  functions   embed_fwd / grad_step / full_step / apply_step /
              head_grad_step / head_apply_step / predict — the exact set
              the rust coordinator drives through PJRT (see DESIGN.md §1)

Everything is shape-static so each function AOT-lowers to one HLO module.
Parameters travel as a flat, name-sorted list of f32 arrays; the manifest
written by aot.py records that order and the rust side never hardcodes it.

GST semantics live here in miniature:

  * ``grad_step`` backprops through exactly the sampled segment batch; the
    stale aggregate enters as a plain input (a constant w.r.t. autodiff),
    which is the whole memory story of the paper — activations for
    non-sampled segments simply never exist.
  * SED (Eq. 1) arrives pre-folded: rust passes ``eta_s`` (the up-weight of
    the fresh segment) and ``stale_sum`` (the eta-weighted sum of kept stale
    embeddings), so p never appears at this layer.
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels
from .config import VariantConfig

# Number of segment slots in the full-graph (all-segments-backprop) step.
# Graphs with more segments than this cannot run Full Graph Training — that
# is the scaled analogue of the paper's 16 GB OOM boundary (see memory/).
FULL_JMAX = 20


# ---------------------------------------------------------------------------
# Parameter initialization (deterministic; numpy RNG seeded per variant)
# ---------------------------------------------------------------------------

def _glorot(rng, fan_in, fan_out):
    limit = float(np.sqrt(6.0 / (fan_in + fan_out)))
    return rng.uniform(-limit, limit, size=(fan_in, fan_out)).astype(np.float32)


def init_params(cfg: VariantConfig, seed: int = 0):
    """Build the parameter dict for a variant. Names sorted == wire order."""
    rng = np.random.default_rng(seed)
    p = {}
    f, h, c = cfg.feat, cfg.hidden, cfg.classes

    # pre-process layer (paper tbl. 5: 1 pre layer for gcn/sage; gps still
    # needs an input projection F->H, so we keep it for all backbones).
    p["pre_w"] = _glorot(rng, f, h)
    p["pre_b"] = np.zeros((h,), np.float32)
    p["pre_alpha"] = np.full((1,), 0.25, np.float32)

    for i in range(cfg.mp_layers):
        if cfg.backbone == "gcn":
            p[f"conv{i}_w"] = _glorot(rng, h, h)
            p[f"conv{i}_b"] = np.zeros((h,), np.float32)
            p[f"conv{i}_alpha"] = np.full((1,), 0.25, np.float32)
        elif cfg.backbone == "sage":
            p[f"conv{i}_wself"] = _glorot(rng, h, h)
            p[f"conv{i}_wneigh"] = _glorot(rng, h, h)
            p[f"conv{i}_b"] = np.zeros((h,), np.float32)
            p[f"conv{i}_alpha"] = np.full((1,), 0.25, np.float32)
        elif cfg.backbone == "gps":
            # local half: SAGE conv
            p[f"conv{i}_wself"] = _glorot(rng, h, h)
            p[f"conv{i}_wneigh"] = _glorot(rng, h, h)
            p[f"conv{i}_b"] = np.zeros((h,), np.float32)
            p[f"conv{i}_alpha"] = np.full((1,), 0.25, np.float32)
            # global half: linear attention projections
            for proj in ("q", "k", "v", "o"):
                p[f"attn{i}_{proj}w"] = _glorot(rng, h, h)
                p[f"attn{i}_{proj}b"] = np.zeros((h,), np.float32)
            # feed-forward
            p[f"ffn{i}_w1"] = _glorot(rng, h, 2 * h)
            p[f"ffn{i}_b1"] = np.zeros((2 * h,), np.float32)
            p[f"ffn{i}_w2"] = _glorot(rng, 2 * h, h)
            p[f"ffn{i}_b2"] = np.zeros((h,), np.float32)
        else:
            raise ValueError(cfg.backbone)

    # post-process layer (per-node, before pooling)
    p["post_w"] = _glorot(rng, h, h)
    p["post_b"] = np.zeros((h,), np.float32)
    p["post_alpha"] = np.full((1,), 0.25, np.float32)

    if cfg.dataset == "malnet":
        # prediction head F' (finetuned by +F): MLP H -> H -> C
        p["head_w1"] = _glorot(rng, h, h)
        p["head_b1"] = np.zeros((h,), np.float32)
        p["head_alpha"] = np.full((1,), 0.25, np.float32)
        p["head_w2"] = _glorot(rng, h, c)
        p["head_b2"] = np.zeros((c,), np.float32)
    else:  # tpu: runtime head lives inside F (F' = sum), per paper sec. 5.3
        p["rt_w1"] = _glorot(rng, h, h)
        p["rt_b1"] = np.zeros((h,), np.float32)
        p["rt_alpha"] = np.full((1,), 0.25, np.float32)
        p["rt_w2"] = _glorot(rng, h, 1)
        p["rt_b2"] = np.zeros((1,), np.float32)
    return p


def param_order(params):
    return sorted(params.keys())


def head_param_names(cfg: VariantConfig, params):
    """Parameters belonging to the prediction head F' (the +F target)."""
    if cfg.dataset != "malnet":
        return []  # tpu: F' is a parameter-free summation (paper sec. 5.3)
    return [k for k in param_order(params) if k.startswith("head_")]


# ---------------------------------------------------------------------------
# Backbones (per-node encoders). All return (B, N, H), masked.
# ---------------------------------------------------------------------------

def _prelu_linear(x, w, b, alpha):
    return kernels.linear(x, w, b, alpha, act=kernels.ACT_PRELU)


def _sage_conv(p, i, h, adj):
    """GraphSAGE mean conv: prelu(h W_self + (D^-1 A h) W_neigh + b)."""
    neigh = kernels.adj_matmul(adj, h)  # adj is row-mean normalized
    z = (kernels.linear(h, p[f"conv{i}_wself"],
                        jnp.zeros_like(p[f"conv{i}_b"]))
         + kernels.linear(neigh, p[f"conv{i}_wneigh"], p[f"conv{i}_b"]))
    a = p[f"conv{i}_alpha"][0]
    return jnp.where(z >= 0.0, z, a * z)


def _backbone_nodes(cfg, p, nodes, adj, mask):
    """Shared per-node encoding: pre -> mp_layers convs -> post."""
    h = _prelu_linear(nodes, p["pre_w"], p["pre_b"], p["pre_alpha"])
    for i in range(cfg.mp_layers):
        if cfg.backbone == "gcn":
            agg = kernels.adj_matmul(adj, h)  # \hat{A} h  (sym + self loop)
            h = _prelu_linear(agg, p[f"conv{i}_w"], p[f"conv{i}_b"],
                              p[f"conv{i}_alpha"])
        elif cfg.backbone == "sage":
            h = _sage_conv(p, i, h, adj)
        else:  # gps-lite: local SAGE conv + linear attention + FFN, residual
            local = _sage_conv(p, i, h, adj)
            h = h + local
            q = kernels.linear(h, p[f"attn{i}_qw"], p[f"attn{i}_qb"])
            k = kernels.linear(h, p[f"attn{i}_kw"], p[f"attn{i}_kb"])
            v = kernels.linear(h, p[f"attn{i}_vw"], p[f"attn{i}_vb"])
            att = kernels.linear_attention(q, k, v, mask)
            h = h + kernels.linear(att, p[f"attn{i}_ow"], p[f"attn{i}_ob"])
            ff = kernels.linear(h, p[f"ffn{i}_w1"], p[f"ffn{i}_b1"],
                                act=kernels.ACT_RELU)
            h = h + kernels.linear(ff, p[f"ffn{i}_w2"], p[f"ffn{i}_b2"])
        h = h * mask[..., None]
    h = _prelu_linear(h, p["post_w"], p["post_b"], p["post_alpha"])
    return h * mask[..., None]


def segment_embed(cfg, p, nodes, adj, mask):
    """F(segment): the quantity stored in the historical table T.

    malnet: masked-mean-pooled node embedding, shape (B, H)
    tpu:    per-segment runtime contribution, shape (B, 1) — the per-node
            runtime head is applied inside F and sum-pooled (paper sec. 5.3)
    """
    h = _backbone_nodes(cfg, p, nodes, adj, mask)
    if cfg.dataset == "malnet":
        denom = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
        return jnp.sum(h, axis=1) / denom  # (B, H)
    r = _prelu_linear(h, p["rt_w1"], p["rt_b1"], p["rt_alpha"])
    r = kernels.linear(r, p["rt_w2"], p["rt_b2"])[..., 0]  # (B, N)
    return jnp.sum(r * mask, axis=1, keepdims=True)  # (B, 1)


def head_logits(p, h_graph):
    """F' for malnet: 2-layer MLP over the aggregated graph embedding."""
    z = _prelu_linear(h_graph, p["head_w1"], p["head_b1"], p["head_alpha"])
    return kernels.linear(z, p["head_w2"], p["head_b2"])  # (B, C)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def pairwise_hinge(yhat, pair_mask):
    """Paper App. B: sum_{ij} I[y_i > y_j] max(0, 1 - (yhat_i - yhat_j)).

    pair_mask[i, j] = 1 where y_i > y_j AND (i, j) are configs of the same
    graph (rust builds it; ranking across different graphs is meaningless).
    """
    diff = yhat[:, None] - yhat[None, :]
    loss = jnp.maximum(0.0, 1.0 - diff) * pair_mask
    return jnp.sum(loss) / jnp.maximum(jnp.sum(pair_mask), 1.0)


def l2_penalty(params, wd):
    return wd * 0.5 * sum(jnp.sum(v * v) for v in params.values())


# ---------------------------------------------------------------------------
# AOT function set. Each builder returns (fn, input_specs, output_specs);
# fn takes flat positional args in spec order. aot.py lowers each fn once
# and records the specs in manifest.json — the rust wire format.
# ---------------------------------------------------------------------------

def _spec(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _param_specs(params, names=None):
    names = names if names is not None else param_order(params)
    return [_spec(f"param:{k}", params[k].shape) for k in names]


def _split(args, n):
    return list(args[:n]), list(args[n:])


def _rebuild(names, flat):
    return dict(zip(names, flat))


def build_embed_fwd(cfg: VariantConfig, params):
    names = param_order(params)
    b, n, f = cfg.batch, cfg.max_nodes, cfg.feat
    specs = _param_specs(params) + [
        _spec("nodes", (b, n, f)),
        _spec("adj", (b, n, n)),
        _spec("mask", (b, n)),
    ]

    def fn(*args):
        flat, (nodes, adj, mask) = _split(args, len(names))
        p = _rebuild(names, flat)
        return (segment_embed(cfg, p, nodes, adj, mask),)

    table_dim = cfg.hidden if cfg.dataset == "malnet" else 1
    outs = [_spec("h", (b, table_dim))]
    return fn, specs, outs


def build_grad_step(cfg: VariantConfig, params):
    """One GST training step over a batch of sampled segments.

    malnet aggregation (mean pooling over J segments, SED pre-folded):
        h_graph = (eta_s * h_s + stale_sum) * inv_j
    tpu aggregation (sum pooling, head inside F):
        yhat = eta_s * r_s + stale_sum
    """
    names = param_order(params)
    b, n, f, h = cfg.batch, cfg.max_nodes, cfg.feat, cfg.hidden
    td = h if cfg.dataset == "malnet" else 1
    specs = _param_specs(params) + [
        _spec("nodes", (b, n, f)),
        _spec("adj", (b, n, n)),
        _spec("mask", (b, n)),
        _spec("stale_sum", (b, td)),
        _spec("eta_s", (b,)),
        _spec("inv_j", (b,)),
    ]
    if cfg.dataset == "malnet":
        specs.append(_spec("labels", (b,), "s32"))
    else:
        specs.append(_spec("pair_mask", (b, b)))
    wd = cfg.opt.weight_decay

    def fn(*args):
        flat, data = _split(args, len(names))
        nodes, adj, mask, stale_sum, eta_s, inv_j, target = data

        def loss_fn(p):
            hs = segment_embed(cfg, p, nodes, adj, mask)  # (B, td)
            if cfg.dataset == "malnet":
                h_graph = (eta_s[:, None] * hs + stale_sum) * inv_j[:, None]
                task = cross_entropy(head_logits(p, h_graph), target)
            else:
                yhat = (eta_s[:, None] * hs + stale_sum)[:, 0]
                task = pairwise_hinge(yhat, target)
            return task + l2_penalty(p, wd), hs

        p = _rebuild(names, flat)
        (loss, hs), grads = jax.value_and_grad(loss_fn, has_aux=True)(p)
        return (loss, *[grads[k] for k in names], hs)

    outs = ([_spec("loss", ())] + [_spec(f"grad:{k}", params[k].shape)
                                   for k in names] + [_spec("h_s", (b, td))])
    return fn, specs, outs


def build_full_step(cfg: VariantConfig, params):
    """Full Graph Training step: backprop through ALL segments of one graph.

    Memory grows with the number of live segment slots (FULL_JMAX), which is
    why this exists only as the baseline — the paper's OOM rows fall out of
    the memory model when J exceeds the budget. malnet only (the tpu
    pairwise loss needs multiple graphs per step and is OOM in the paper
    anyway).
    """
    assert cfg.dataset == "malnet"
    names = param_order(params)
    jm, n, f = FULL_JMAX, cfg.max_nodes, cfg.feat
    specs = _param_specs(params) + [
        _spec("nodes", (jm, n, f)),
        _spec("adj", (jm, n, n)),
        _spec("mask", (jm, n)),
        _spec("seg_mask", (jm,)),
        _spec("labels", (1,), "s32"),
    ]
    wd = cfg.opt.weight_decay

    def fn(*args):
        flat, (nodes, adj, mask, seg_mask, labels) = _split(args, len(names))

        def loss_fn(p):
            hs = segment_embed(cfg, p, nodes, adj, mask)  # (Jm, H)
            denom = jnp.maximum(jnp.sum(seg_mask), 1.0)
            h_graph = (jnp.sum(hs * seg_mask[:, None], axis=0) / denom)[None]
            task = cross_entropy(head_logits(p, h_graph), labels)
            return task + l2_penalty(p, wd), hs

        p = _rebuild(names, flat)
        (loss, hs), grads = jax.value_and_grad(loss_fn, has_aux=True)(p)
        return (loss, *[grads[k] for k in names], hs)

    outs = ([_spec("loss", ())] + [_spec(f"grad:{k}", params[k].shape)
                                   for k in names]
            + [_spec("h_all", (jm, cfg.hidden))])
    return fn, specs, outs


def _adam(p, m, v, g, t, lr, opt):
    m2 = opt.beta1 * m + (1.0 - opt.beta1) * g
    v2 = opt.beta2 * v + (1.0 - opt.beta2) * g * g
    mhat = m2 / (1.0 - jnp.power(opt.beta1, t))
    vhat = v2 / (1.0 - jnp.power(opt.beta2, t))
    return p - lr * mhat / (jnp.sqrt(vhat) + opt.eps), m2, v2


def build_apply_step(cfg: VariantConfig, params, names=None):
    """Adam update over (a subset of) parameters. L3 averages grads across
    data-parallel workers / accumulates over S segments, then calls this
    once — that separation is what makes S>1 and multi-GPU simulation free.
    """
    names = names if names is not None else param_order(params)
    specs = ([_spec(f"param:{k}", params[k].shape) for k in names]
             + [_spec(f"m:{k}", params[k].shape) for k in names]
             + [_spec(f"v:{k}", params[k].shape) for k in names]
             + [_spec(f"grad:{k}", params[k].shape) for k in names]
             + [_spec("t", ()), _spec("lr", ())])
    opt = cfg.opt

    def fn(*args):
        k = len(names)
        ps, ms, vs, gs = (args[:k], args[k:2 * k], args[2 * k:3 * k],
                          args[3 * k:4 * k])
        t, lr = args[4 * k], args[4 * k + 1]
        outs = [_adam(p, m, v, g, t, lr, opt)
                for p, m, v, g in zip(ps, ms, vs, gs)]
        return (tuple(o[0] for o in outs) + tuple(o[1] for o in outs)
                + tuple(o[2] for o in outs))

    outs = ([_spec(f"param:{k}", params[k].shape) for k in names]
            + [_spec(f"m:{k}", params[k].shape) for k in names]
            + [_spec(f"v:{k}", params[k].shape) for k in names])
    return fn, specs, outs


def build_head_grad_step(cfg: VariantConfig, params):
    """+F finetuning: grads of the CE loss w.r.t. head params only, with all
    segment embeddings served up-to-date from the table (Alg. 2, lines 11+).
    """
    assert cfg.dataset == "malnet"
    hnames = head_param_names(cfg, params)
    b, h = cfg.batch, cfg.hidden
    specs = ([_spec(f"param:{k}", params[k].shape) for k in hnames]
             + [_spec("h_graph", (b, h)), _spec("labels", (b,), "s32")])
    wd = cfg.opt.weight_decay

    def fn(*args):
        flat, (h_graph, labels) = _split(args, len(hnames))

        def loss_fn(hp):
            task = cross_entropy(head_logits(hp, h_graph), labels)
            return task + l2_penalty(hp, wd)

        hp = _rebuild(hnames, flat)
        loss, grads = jax.value_and_grad(loss_fn)(hp)
        return (loss, *[grads[k] for k in hnames])

    outs = [_spec("loss", ())] + [_spec(f"grad:{k}", params[k].shape)
                                  for k in hnames]
    return fn, specs, outs


def build_predict(cfg: VariantConfig, params):
    """Eval-time F' over an aggregated graph embedding."""
    assert cfg.dataset == "malnet"
    hnames = head_param_names(cfg, params)
    b, h = cfg.batch, cfg.hidden
    specs = ([_spec(f"param:{k}", params[k].shape) for k in hnames]
             + [_spec("h_graph", (b, h))])

    def fn(*args):
        flat, (h_graph,) = _split(args, len(hnames))
        return (head_logits(_rebuild(hnames, flat), h_graph),)

    outs = [_spec("logits", (b, cfg.classes))]
    return fn, specs, outs


def function_set(cfg: VariantConfig, params):
    """All AOT targets for a variant, name -> (fn, in_specs, out_specs)."""
    fns = {
        "embed_fwd": build_embed_fwd(cfg, params),
        "grad_step": build_grad_step(cfg, params),
        "apply_step": build_apply_step(cfg, params),
    }
    if cfg.dataset == "malnet":
        fns["full_step"] = build_full_step(cfg, params)
        fns["head_grad_step"] = build_head_grad_step(cfg, params)
        fns["head_apply_step"] = build_apply_step(
            cfg, params, names=head_param_names(cfg, params))
        fns["predict"] = build_predict(cfg, params)
    return fns
