//! Data-parallel worker scaling: per-step wall clock for a fixed
//! 4-micro-batch optimizer step as fork-join threads are added — the
//! hot-path speedup the `GstCore` refactor buys. Also asserts the
//! worker-invariance guarantee (identical test metric across the sweep).
//! Emits BENCH_worker_scaling.json for the CI perf trajectory.
//!
//!     cargo bench --bench worker_scaling

#[path = "harness.rs"]
mod harness;

use gst::datasets::{MalnetDataset, MalnetSplit};
use gst::runtime::Engine;
use gst::train::{MalnetTrainer, Method, TrainConfig};

fn main() {
    let Some(dir) = harness::artifacts("malnet_sage_n128") else {
        println!("worker_scaling: artifacts not built, skipping");
        harness::emit_json("worker_scaling", &[], true);
        return;
    };
    let eng = Engine::open(&dir).unwrap();
    let data = MalnetDataset::generate(MalnetSplit::Tiny, 40, 0);
    let mut series = Vec::new();
    let mut metrics = Vec::new();
    println!("\nworker scaling (4 micro-batches/step, GST+ED, malnet-tiny):");
    for workers in [1usize, 2, 4] {
        let cfg = TrainConfig {
            method: Method::GstED,
            epochs: 4,
            finetune_epochs: 0,
            eval_every: 99,
            seed: 0,
            workers,
            micro_batches: 4,
            ..TrainConfig::default()
        };
        let mut tr = MalnetTrainer::new(&eng, &data, cfg).unwrap();
        let res = tr.train().unwrap();
        println!(
            "{:<44} {:>10.1} ms/step (test {:.4})",
            format!("workers={workers}"),
            res.step_ms,
            res.test_metric,
        );
        series.push((format!("workers={workers}"), res.step_ms));
        metrics.push(res.test_metric);
    }
    assert!(
        metrics.iter().all(|&m| m == metrics[0]),
        "worker-count invariance violated: {metrics:?}"
    );
    harness::emit_json("worker_scaling", &series, false);
}
