"""Section-Perf analysis: L1 analytic TPU estimates + L2 HLO audit.

interpret=True pallas gives CPU-numpy timings only, so L1 TPU performance
is *estimated* from the BlockSpec schedules (VMEM residency, MXU pass
utilization) — see DESIGN.md §7. The L2 audit parses the lowered HLO text
and counts the expensive ops, catching recomputation regressions (e.g. the
backbone being traced twice into grad_step).

Usage: python -m compile.perf_report [--artifacts DIR]
"""

import argparse
import os
import re

from .config import default_variants
from .kernels import attention, matmul, spmm

VMEM_BUDGET = 16 * 1024 * 1024  # v3 VMEM per core


def l1_report(cfg):
    """Per-kernel VMEM + MXU estimates at a variant's shapes."""
    b, n, f, h = cfg.batch, cfg.max_nodes, cfg.feat, cfg.hidden
    rows = []
    # dense layers: (B*N, F->H) and (B*N, H->H)
    for (name, m, k, nn) in [
        (f"linear {f}->{h}", b * n, f, h),
        (f"linear {h}->{h}", b * n, h, h),
    ]:
        rows.append((
            f"matmul_bias_act {name}",
            matmul.vmem_bytes(m, k, nn),
            matmul.mxu_utilization(m, k, nn),
        ))
    rows.append((
        f"adj_matmul N={n} F={h}",
        spmm.vmem_bytes(n, h),
        spmm.mxu_utilization(n, h),
    ))
    if cfg.backbone == "gps":
        rows.append((
            f"linear_attention N={n} H={h}",
            attention.vmem_bytes(n, h),
            float("nan"),
        ))
    return rows


_OPS = ("dot(", "dot_general", "convolution(", "while(", "custom-call")


def hlo_op_counts(path):
    text = open(path).read()
    counts = {}
    counts["dot"] = len(re.findall(r"= f32\[[\d,]*\][^=]* dot\(", text))
    counts["while"] = text.count(" while(")
    counts["fusion"] = text.count(" fusion(")
    counts["custom-call"] = text.count("custom-call")
    counts["bytes"] = len(text)
    return counts


def l2_audit(artifacts, variant):
    """grad_step must contain ~2x the dots of embed_fwd (fwd+bwd), not 3x+
    (which would mean XLA re-traced the forward)."""
    vdir = os.path.join(artifacts, variant)
    out = {}
    for fn in ("embed_fwd", "grad_step"):
        p = os.path.join(vdir, f"{fn}.hlo.txt")
        if os.path.isfile(p):
            out[fn] = hlo_op_counts(p)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args(argv)
    for cfg in default_variants():
        print(f"\n== {cfg.name}")
        print("  L1 analytic estimates (BlockSpec schedules):")
        for (name, vmem, util) in l1_report(cfg):
            ok = "ok" if vmem < VMEM_BUDGET else "OVER"
            print(f"    {name:<34} vmem {vmem/1024:>8.0f} KiB [{ok}]"
                  f"  mxu-util {util:>6.1%}" if util == util else
                  f"    {name:<34} vmem {vmem/1024:>8.0f} KiB [{ok}]")
        audit = l2_audit(args.artifacts, cfg.name)
        if audit:
            print("  L2 HLO audit:")
            for fn, c in audit.items():
                print(f"    {fn:<12} dots={c['dot']:<4} while={c['while']:<3}"
                      f" fusions={c['fusion']:<4} "
                      f"custom-calls={c['custom-call']}")
            if "embed_fwd" in audit and "grad_step" in audit:
                ratio = (audit["grad_step"]["dot"]
                         / max(1, audit["embed_fwd"]["dot"]))
                flag = "ok" if ratio <= 3.05 else "RECOMPUTATION?"
                print(f"    grad/embed dot ratio = {ratio:.2f} [{flag}]")


if __name__ == "__main__":
    main()
