//! Typed view of `artifacts/<variant>/manifest.json`.

use crate::segment::AdjNorm;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

/// Tensor dtype on the wire (everything is f32 except labels).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    S32,
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct FnSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub head: bool,
}

impl ParamSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Parsed manifest for one artifact variant.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub variant: String,
    pub dataset: String,
    pub backbone: String,
    pub batch: usize,
    pub max_nodes: usize,
    pub feat: usize,
    pub hidden: usize,
    pub classes: usize,
    pub table_dim: usize,
    pub full_jmax: usize,
    pub adj_norm: AdjNorm,
    pub lr: f32,
    pub head_lr: f32,
    pub params: Vec<ParamSpec>,
    pub functions: BTreeMap<String, FnSpec>,
}

fn tensor_spec(j: &Json) -> Result<TensorSpec> {
    let name = j.at("name").as_str().ok_or_else(|| anyhow!("spec name"))?;
    let shape = j
        .at("shape")
        .as_arr()
        .ok_or_else(|| anyhow!("spec shape"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("shape dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = match j.at("dtype").as_str() {
        Some("f32") => Dtype::F32,
        Some("s32") => Dtype::S32,
        other => bail!("unknown dtype {other:?}"),
    };
    Ok(TensorSpec { name: name.to_string(), shape, dtype })
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        Manifest::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let v = j.at("variant");
        let getu = |obj: &Json, k: &str| -> Result<usize> {
            obj.get(k)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow!("missing usize `{k}`"))
        };
        let opt = v.at("opt");
        let params = j
            .at("params")
            .as_arr()
            .ok_or_else(|| anyhow!("params"))?
            .iter()
            .map(|p| {
                let name = p
                    .at("name")
                    .as_str()
                    .ok_or_else(|| anyhow!("param name"))?
                    .to_string();
                let shape = p
                    .at("shape")
                    .as_arr()
                    .ok_or_else(|| anyhow!("param `{name}`: shape"))?
                    .iter()
                    .map(|d| {
                        d.as_usize().ok_or_else(|| {
                            anyhow!(
                                "param `{name}`: malformed shape \
                                 dimension {d:?} (want a non-negative \
                                 integer)"
                            )
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(ParamSpec {
                    name,
                    shape,
                    head: p.at("head").as_bool().unwrap_or(false),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut functions = BTreeMap::new();
        for (name, f) in
            j.at("functions").as_obj().ok_or_else(|| anyhow!("functions"))?
        {
            let inputs = f
                .at("inputs")
                .as_arr()
                .ok_or_else(|| anyhow!("inputs"))?
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = f
                .at("outputs")
                .as_arr()
                .ok_or_else(|| anyhow!("outputs"))?
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            functions.insert(
                name.clone(),
                FnSpec {
                    file: f
                        .at("file")
                        .as_str()
                        .ok_or_else(|| anyhow!("file"))?
                        .to_string(),
                    inputs,
                    outputs,
                },
            );
        }
        let adj_norm_str =
            v.at("adj_norm").as_str().ok_or_else(|| anyhow!("adj_norm"))?;
        Ok(Manifest {
            variant: v
                .at("name")
                .as_str()
                .ok_or_else(|| anyhow!("variant name"))?
                .to_string(),
            dataset: v
                .at("dataset")
                .as_str()
                .ok_or_else(|| anyhow!("dataset"))?
                .to_string(),
            backbone: v
                .at("backbone")
                .as_str()
                .ok_or_else(|| anyhow!("backbone"))?
                .to_string(),
            batch: getu(v, "batch")?,
            max_nodes: getu(v, "max_nodes")?,
            feat: getu(v, "feat")?,
            hidden: getu(v, "hidden")?,
            classes: getu(v, "classes")?,
            table_dim: getu(j, "table_dim")?,
            full_jmax: getu(j, "full_jmax")?,
            adj_norm: AdjNorm::parse(adj_norm_str)
                .ok_or_else(|| anyhow!("bad adj_norm {adj_norm_str}"))?,
            lr: opt.at("lr").as_f64().ok_or_else(|| anyhow!("lr"))? as f32,
            head_lr: opt
                .at("head_lr")
                .as_f64()
                .ok_or_else(|| anyhow!("head_lr"))? as f32,
            params,
            functions,
        })
    }

    pub fn func(&self, name: &str) -> Result<&FnSpec> {
        self.functions
            .get(name)
            .ok_or_else(|| anyhow!("variant {} has no fn {name}", self.variant))
    }

    /// Indices (into `params`) of the prediction-head parameters.
    pub fn head_indices(&self) -> Vec<usize> {
        self.params
            .iter()
            .enumerate()
            .filter(|(_, p)| p.head)
            .map(|(i, _)| i)
            .collect()
    }
}

#[cfg(test)]
pub(crate) mod tests_support {
    use super::*;

    /// A minimal hand-built manifest for unit tests that don't need HLO.
    pub(crate) fn tiny_manifest() -> Manifest {
        Manifest {
            variant: "test".into(),
            dataset: "malnet".into(),
            backbone: "sage".into(),
            batch: 2,
            max_nodes: 4,
            feat: 3,
            hidden: 2,
            classes: 5,
            table_dim: 2,
            full_jmax: 12,
            adj_norm: AdjNorm::RowMean,
            lr: 0.01,
            head_lr: 0.001,
            params: vec![
                ParamSpec { name: "a".into(), shape: vec![2, 2], head: false },
                ParamSpec { name: "head_b".into(), shape: vec![2], head: true },
            ],
            functions: BTreeMap::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "full_jmax": 12,
      "table_dim": 64,
      "variant": {"name":"malnet_sage_n128","dataset":"malnet",
                  "backbone":"sage","batch":8,"max_nodes":128,"feat":16,
                  "hidden":64,"classes":5,"mp_layers":2,
                  "adj_norm":"row_mean",
                  "opt":{"lr":0.01,"head_lr":0.001,"beta1":0.9,
                         "beta2":0.999,"eps":1e-8,"weight_decay":1e-4}},
      "params":[{"name":"pre_w","shape":[16,64],"dtype":"f32","head":false},
                {"name":"head_w2","shape":[64,5],"dtype":"f32","head":true}],
      "functions":{"predict":{"file":"predict.hlo.txt",
        "inputs":[{"name":"param:head_w2","shape":[64,5],"dtype":"f32"},
                  {"name":"h_graph","shape":[8,64],"dtype":"f32"}],
        "outputs":[{"name":"logits","shape":[8,5],"dtype":"f32"}]}}
    }"#;

    #[test]
    fn parses_sample() {
        let j = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&j).unwrap();
        assert_eq!(m.variant, "malnet_sage_n128");
        assert_eq!(m.batch, 8);
        assert_eq!(m.adj_norm, AdjNorm::RowMean);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.head_indices(), vec![1]);
        let f = m.func("predict").unwrap();
        assert_eq!(f.inputs.len(), 2);
        assert_eq!(f.outputs[0].elems(), 40);
        assert!((m.lr - 0.01).abs() < 1e-9);
    }

    #[test]
    fn malformed_param_dim_errors_with_param_name() {
        // a negative dimension used to be silently read as 0, collapsing
        // the parameter to zero elements — it must be a parse error that
        // names the offending parameter
        let bad = SAMPLE.replace(
            r#""name":"pre_w","shape":[16,64]"#,
            r#""name":"pre_w","shape":[16,-64]"#,
        );
        let j = Json::parse(&bad).unwrap();
        let err = Manifest::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("pre_w"), "error names the param: {err}");
        assert!(err.contains("malformed shape"), "{err}");
    }

    #[test]
    fn missing_fn_errors() {
        let j = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&j).unwrap();
        assert!(m.func("grad_step").is_err());
    }

    #[test]
    fn real_artifacts_parse_if_present() {
        let root = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(root).is_dir() {
            return;
        }
        for entry in std::fs::read_dir(root).unwrap().flatten() {
            let dir = entry.path();
            if dir.join("manifest.json").is_file() {
                let m = Manifest::load(dir.to_str().unwrap()).unwrap();
                assert!(!m.params.is_empty());
                assert!(m.functions.contains_key("grad_step"));
            }
        }
    }
}
