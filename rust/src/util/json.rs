//! Minimal JSON parser/writer (serde is unavailable offline — DESIGN.md §3).
//!
//! Used for the AOT `manifest.json` files, experiment configs and run
//! outputs. Full JSON spec coverage except: no `\u` surrogate-pair pedantry
//! beyond BMP decoding, and numbers parse as f64 (manifest shapes are small
//! integers — lossless).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects are ordered maps so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors (panic-free; used pervasively by manifest code) --

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` chains that must exist — errors name the path.
    pub fn at(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Non-negative integer view; `None` for negative or fractional
    /// numbers (a saturating float cast here would silently turn a
    /// malformed `-64` into `0`).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            (x >= 0.0 && x.fract() == 0.0 && x <= usize::MAX as f64)
                .then_some(x as usize)
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- constructors for writer-side ergonomics --

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Serialize compactly (deterministic: objects are BTreeMaps).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            s.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        other => {
                            return Err(format!("bad escape {other:?}"))
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":{}}"#).unwrap();
        assert_eq!(j.at("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at("a").as_arr().unwrap()[2].at("b").as_str(),
            Some("x")
        );
    }

    #[test]
    fn as_usize_rejects_non_indices() {
        assert_eq!(Json::Num(64.0).as_usize(), Some(64));
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        assert_eq!(Json::Num(-64.0).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Str("3".into()).as_usize(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"dims":{"B":8,"N":128},"name":"x","ok":true,"xs":[1.5,-2,null]}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""éA""#).unwrap();
        assert_eq!(j, Json::Str("éA".into()));
    }

    #[test]
    fn writer_escapes_control() {
        let s = Json::Str("a\"b\\c\nd".into()).to_string();
        assert_eq!(s, r#""a\"b\\c\nd""#);
    }
}
