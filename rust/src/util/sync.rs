//! Contention-aware lock wrappers (`TimedMutex`, `TimedRwLock`).
//!
//! The GST compute phase shares a handful of locks across worker
//! threads — the engine's executable/parameter-literal caches, its call
//! counters, and the fill-block cache. These wrappers make that
//! contention *measurable*: every acquisition first tries the lock
//! without blocking (the steady-state fast path costs two relaxed
//! atomic increments), and only a failed try falls back to a timed
//! blocking acquire, accumulating the wait into [`LockStats`].
//!
//! Telemetry-only by construction: the wrappers never change locking
//! semantics (same poisoning behavior, same guards), so wrapping a lock
//! can never change trained parameters — only explain where the wall
//! clock went.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{
    Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
    TryLockError,
};
use std::time::Instant;

use crate::util::json::Json;

/// Cumulative contention counters of one lock.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LockStats {
    /// Total time spent blocked waiting for the lock, in ns.
    pub wait_ns: u64,
    /// Total acquisitions (fast path + contended).
    pub acquisitions: u64,
    /// Acquisitions that found the lock held and had to block.
    pub contended: u64,
}

impl LockStats {
    pub fn wait_ms(&self) -> f64 {
        self.wait_ns as f64 / 1e6
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("wait_ms", Json::num(self.wait_ms())),
            ("acquisitions", Json::num(self.acquisitions as f64)),
            ("contended", Json::num(self.contended as f64)),
        ])
    }
}

/// Shared counter cell (one per wrapped lock).
#[derive(Default)]
struct Counters {
    wait_ns: AtomicU64,
    acquisitions: AtomicU64,
    contended: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> LockStats {
        LockStats {
            wait_ns: self.wait_ns.load(Ordering::Relaxed),
            acquisitions: self.acquisitions.load(Ordering::Relaxed),
            contended: self.contended.load(Ordering::Relaxed),
        }
    }

    fn blocked(&self, waited: Instant) {
        self.contended.fetch_add(1, Ordering::Relaxed);
        self.wait_ns.fetch_add(
            waited.elapsed().as_nanos() as u64,
            Ordering::Relaxed,
        );
    }
}

/// `Mutex` that counts acquisitions and accumulates blocked wait time.
pub struct TimedMutex<T> {
    inner: Mutex<T>,
    counters: Counters,
}

impl<T> TimedMutex<T> {
    pub fn new(value: T) -> TimedMutex<T> {
        TimedMutex { inner: Mutex::new(value), counters: Counters::default() }
    }

    /// Acquire the lock; panics on poison (matching the bare
    /// `.lock().expect(...)` idiom this wrapper replaces).
    pub fn lock(&self) -> MutexGuard<'_, T> {
        self.counters.acquisitions.fetch_add(1, Ordering::Relaxed);
        match self.inner.try_lock() {
            Ok(g) => g,
            Err(TryLockError::WouldBlock) => {
                let t0 = Instant::now();
                let g = self.inner.lock().expect("timed mutex poisoned");
                self.counters.blocked(t0);
                g
            }
            Err(TryLockError::Poisoned(_)) => {
                panic!("timed mutex poisoned")
            }
        }
    }

    /// Cumulative contention counters since construction.
    pub fn stats(&self) -> LockStats {
        self.counters.snapshot()
    }
}

/// `RwLock` counterpart: reads and writes share one counter set (the
/// interesting signal is total blocked time, not the read/write split).
pub struct TimedRwLock<T> {
    inner: RwLock<T>,
    counters: Counters,
}

impl<T> TimedRwLock<T> {
    pub fn new(value: T) -> TimedRwLock<T> {
        TimedRwLock {
            inner: RwLock::new(value),
            counters: Counters::default(),
        }
    }

    pub fn read(&self) -> RwLockReadGuard<'_, T> {
        self.counters.acquisitions.fetch_add(1, Ordering::Relaxed);
        match self.inner.try_read() {
            Ok(g) => g,
            Err(TryLockError::WouldBlock) => {
                let t0 = Instant::now();
                let g = self.inner.read().expect("timed rwlock poisoned");
                self.counters.blocked(t0);
                g
            }
            Err(TryLockError::Poisoned(_)) => {
                panic!("timed rwlock poisoned")
            }
        }
    }

    pub fn write(&self) -> RwLockWriteGuard<'_, T> {
        self.counters.acquisitions.fetch_add(1, Ordering::Relaxed);
        match self.inner.try_write() {
            Ok(g) => g,
            Err(TryLockError::WouldBlock) => {
                let t0 = Instant::now();
                let g = self.inner.write().expect("timed rwlock poisoned");
                self.counters.blocked(t0);
                g
            }
            Err(TryLockError::Poisoned(_)) => {
                panic!("timed rwlock poisoned")
            }
        }
    }

    pub fn stats(&self) -> LockStats {
        self.counters.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uncontended_lock_counts_without_waiting() {
        let m = TimedMutex::new(0usize);
        for _ in 0..3 {
            *m.lock() += 1;
        }
        let s = m.stats();
        assert_eq!(*m.lock(), 3);
        assert_eq!(s.acquisitions, 3);
        assert_eq!(s.contended, 0);
        assert_eq!(s.wait_ns, 0);
    }

    #[test]
    fn contended_lock_records_wait_time() {
        let m = TimedMutex::new(());
        std::thread::scope(|scope| {
            let g = m.lock();
            let t = scope.spawn(|| {
                // blocks until the holder drops its guard
                drop(m.lock());
            });
            // acquisitions increments before the try, so once it reads 2
            // the spawned thread is at (or past) its failing try_lock
            while m.stats().acquisitions < 2 {
                std::thread::yield_now();
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
            drop(g);
            t.join().unwrap();
        });
        let s = m.stats();
        assert_eq!(s.acquisitions, 2);
        assert_eq!(s.contended, 1);
        assert!(s.wait_ns > 0, "blocked acquire recorded no wait");
        assert!(s.wait_ms() > 0.0);
    }

    #[test]
    fn rwlock_counts_reads_and_writes() {
        let l = TimedRwLock::new(5usize);
        assert_eq!(*l.read(), 5);
        *l.write() = 7;
        assert_eq!(*l.read(), 7);
        let s = l.stats();
        assert_eq!(s.acquisitions, 3);
        assert_eq!(s.contended, 0);
    }

    #[test]
    fn rwlock_write_blocked_by_reader_is_contended() {
        let l = TimedRwLock::new(0usize);
        std::thread::scope(|scope| {
            let g = l.read();
            let t = scope.spawn(|| {
                *l.write() = 1;
            });
            while l.stats().acquisitions < 2 {
                std::thread::yield_now();
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
            drop(g);
            t.join().unwrap();
        });
        let s = l.stats();
        assert_eq!(s.acquisitions, 2);
        assert_eq!(s.contended, 1);
        assert!(s.wait_ns > 0);
        assert_eq!(*l.read(), 1);
    }

    #[test]
    fn stats_serialize_to_json() {
        let s = LockStats { wait_ns: 2_000_000, acquisitions: 9, contended: 1 };
        let j = s.to_json();
        assert_eq!(j.at("wait_ms").as_f64(), Some(2.0));
        assert_eq!(j.at("acquisitions").as_f64(), Some(9.0));
        assert_eq!(j.at("contended").as_f64(), Some(1.0));
    }
}
