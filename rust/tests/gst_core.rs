//! GstTask conformance suite: guarantees every task gets from the shared
//! `GstCore` driver, exercised over the real AOT artifacts (skipped when
//! `artifacts/` is not built, like the rest of the integration tier).
//!
//! The pure-logic half of the suite (SED weights per `SedMode`, table
//! write-back versioning, batch-padding rule) lives in unit tests inside
//! `src/train/core.rs`; this file covers what needs a real engine — above
//! all the worker-count invariance contract: `cfg.workers` is an
//! execution knob, so workers=1 and workers=4 must produce **identical
//! parameters** after training.

use gst::datasets::{MalnetDataset, MalnetSplit, TpuDataset};
use gst::obs::analyze;
use gst::obs::ObsConfig;
use gst::runtime::Engine;
use gst::train::{MalnetTrainer, Method, TpuTrainer, TrainConfig};
use gst::util::json::Json;

fn dir(v: &str) -> Option<String> {
    let d = format!("{}/artifacts/{v}", env!("CARGO_MANIFEST_DIR"));
    std::path::Path::new(&d).is_dir().then_some(d)
}

fn cfg(method: Method, workers: usize) -> TrainConfig {
    TrainConfig {
        method,
        epochs: 1,
        finetune_epochs: 0,
        eval_every: 1,
        seed: 5,
        workers,
        micro_batches: 4,
        ..TrainConfig::default()
    }
}

#[test]
fn workers_1_and_4_produce_identical_parameters_malnet() {
    let Some(d) = dir("malnet_sage_n128") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let eng = Engine::open(&d).unwrap();
    let data = MalnetDataset::generate(MalnetSplit::Tiny, 40, 3);
    let run = |workers: usize| {
        let mut tr =
            MalnetTrainer::new(&eng, &data, cfg(Method::GstED, workers))
                .unwrap();
        let res = tr.train().unwrap();
        (tr.ps.values.clone(), tr.ps.m.clone(), res.test_metric)
    };
    let (p1, m1, acc1) = run(1);
    let (p4, m4, acc4) = run(4);
    // identical parameters AND Adam moments => the whole gradient
    // sequence (sampling, SED, staleness, averaging) matched bit-for-bit
    assert_eq!(p1, p4, "parameters diverge with worker count");
    assert_eq!(m1, m4, "Adam moments diverge with worker count");
    assert_eq!(acc1, acc4);
}

#[test]
fn fill_cache_budget_never_changes_parameters() {
    let Some(d) = dir("malnet_sage_n128") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let eng = Engine::open(&d).unwrap();
    let data = MalnetDataset::generate(MalnetSplit::Tiny, 40, 3);
    // fill_cache_mb is execution-only, exactly like workers: a cached
    // fill block is bit-identical to a fresh fill, so any budget (and
    // any worker count on top) trains the same parameters
    let run = |fill_cache_mb: usize, workers: usize| {
        let mut c = cfg(Method::GstED, workers);
        c.fill_cache_mb = fill_cache_mb;
        let mut tr = MalnetTrainer::new(&eng, &data, c).unwrap();
        let res = tr.train().unwrap();
        (tr.ps.values.clone(), tr.ps.m.clone(), res)
    };
    let (p0, m0, r0) = run(0, 1);
    let (p1, m1, r1) = run(64, 1);
    let (p4, m4, r4) = run(64, 4);
    assert_eq!(p0, p1, "parameters diverge with fill cache budget");
    assert_eq!(m0, m1, "Adam moments diverge with fill cache budget");
    assert_eq!(p0, p4, "parameters diverge with cache + workers");
    assert_eq!(m0, m4, "Adam moments diverge with cache + workers");
    assert_eq!(r0.test_metric, r1.test_metric);
    assert_eq!(r0.test_metric, r4.test_metric);
    // the disabled run reports no cache traffic; the budgeted runs hit
    assert_eq!(r0.fill_cache.total(), 0);
    assert!(r1.fill_cache.hits > 0, "expected fill-cache hits");
    // every run serves parameter literals from the engine cache
    assert!(r1.param_cache.hits > 0, "expected param-literal hits");
}

#[test]
fn workers_1_and_4_produce_identical_parameters_tpu() {
    let Some(d) = dir("tpu_sage_n128") else {
        eprintln!("skipping: tpu artifacts not built");
        return;
    };
    let eng = Engine::open(&d).unwrap();
    let data = TpuDataset::generate(6, 6, 11);
    let run = |workers: usize| {
        let mut tr =
            TpuTrainer::new(&eng, &data, cfg(Method::GstEFD, workers))
                .unwrap();
        let res = tr.train().unwrap();
        (tr.ps.values.clone(), res.test_metric)
    };
    let (p1, acc1) = run(1);
    let (p4, acc4) = run(4);
    assert_eq!(p1, p4, "parameters diverge with worker count");
    assert_eq!(acc1, acc4);
}

#[test]
fn table_writeback_versions_advance_during_training() {
    let Some(d) = dir("malnet_sage_n128") else {
        return;
    };
    let eng = Engine::open(&d).unwrap();
    let data = MalnetDataset::generate(MalnetSplit::Tiny, 40, 3);
    let mut tr =
        MalnetTrainer::new(&eng, &data, cfg(Method::GstE, 2)).unwrap();
    assert_eq!(tr.table.coverage(), 0.0);
    tr.train().unwrap();
    let now = tr.steps_done();
    assert!(now > 0);
    assert!(tr.table.coverage() > 0.0);
    // every written entry's version is a real step index (< now), and at
    // least one write happened after the very first optimization step
    let mut min_age = u32::MAX;
    for g in 0..tr.table.num_graphs() {
        for s in 0..tr.table.segments_of(g) {
            if let Some(age) = tr.table.staleness(g, s, now) {
                assert!(age <= now, "version out of range");
                min_age = min_age.min(age);
            }
        }
    }
    assert!(
        min_age < now,
        "no table entry was written by a later training step"
    );
}

#[test]
fn micro_batches_scale_the_effective_batch() {
    let Some(d) = dir("malnet_sage_n128") else {
        return;
    };
    let eng = Engine::open(&d).unwrap();
    let data = MalnetDataset::generate(MalnetSplit::Tiny, 40, 3);
    // 4 micro-batches per step over the same epoch = 1/4 the optimizer
    // applies of the 1-micro-batch run (drop-last grouping)
    let steps = |micro: usize| {
        let mut c = cfg(Method::GstED, 1);
        c.micro_batches = micro;
        let mut tr = MalnetTrainer::new(&eng, &data, c).unwrap();
        tr.train().unwrap();
        // steps_done counts micro-batches; the recorder's step timer
        // counts optimizer steps (groups)
        (tr.steps_done(), tr.obs.step_count())
    };
    let (micro1, groups1) = steps(1);
    let (micro4, groups4) = steps(4);
    assert_eq!(micro1, micro4, "same micro-batch stream either way");
    assert_eq!(groups4, (groups1 + 3) / 4);
}

#[test]
fn observability_sinks_never_change_parameters() {
    let Some(d) = dir("malnet_sage_n128") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let eng = Engine::open(&d).unwrap();
    let data = MalnetDataset::generate(MalnetSplit::Tiny, 40, 3);
    let trace = std::env::temp_dir()
        .join(format!("gst_obs_e2e_{}.jsonl", std::process::id()));
    // recording, tracing, and the heartbeat are execution-only, exactly
    // like workers and the fill cache: same parameters either way
    let run = |obs: ObsConfig| {
        let mut c = cfg(Method::GstEFD, 1);
        c.finetune_epochs = 1;
        c.obs = obs;
        let mut tr = MalnetTrainer::new(&eng, &data, c).unwrap();
        let res = tr.train().unwrap();
        (tr.ps.values.clone(), tr.ps.m.clone(), tr.ps.v.clone(), res)
    };
    let (p0, m0, v0, r0) = run(ObsConfig::default());
    let (p1, m1, v1, r1) = run(ObsConfig {
        record: true,
        trace_out: Some(trace.to_str().unwrap().to_string()),
        log_every: 2,
    });
    assert_eq!(p0, p1, "parameters diverge with observability on");
    assert_eq!(m0, m1, "Adam m moments diverge with observability on");
    assert_eq!(v0, v1, "Adam v moments diverge with observability on");
    assert_eq!(r0.test_metric, r1.test_metric);

    // both runs carry a complete report document; the enabled run fills
    // the telemetry sections
    let rep = &r1.report;
    assert_eq!(rep.at("schema").as_str(), Some("gst-run-report/v3"));
    let phases = rep.at("phases").as_obj().unwrap();
    for key in [
        "step", "sample", "fill", "embed_fwd", "grad", "table_commit",
        "eval", "finetune",
    ] {
        assert!(phases.contains_key(key), "missing phase `{key}`");
    }
    // the in-step leaf phases nest inside `step`, so their breakdown
    // can account for at most the step total
    let ms = |k: &str| phases[k].at("total_ms").as_f64().unwrap();
    let leaves = ms("sample")
        + ms("fill")
        + ms("embed_fwd")
        + ms("grad")
        + ms("table_commit");
    assert!(leaves > 0.0, "no phase time recorded");
    assert!(leaves <= ms("step") * 1.001, "leaf phases exceed step");
    // per-epoch staleness telemetry: one entry per training epoch when
    // enabled, none when disabled
    assert_eq!(rep.at("staleness").as_arr().unwrap().len(), 1);
    assert!(r0.report.at("staleness").as_arr().unwrap().is_empty());
    // GST+EFD draws SED over stale segments
    assert!(rep.at("sed").at("stale_total").as_f64().unwrap() > 0.0);
    assert!(
        rep.at("gauges")
            .at("memory_model_peak_bytes")
            .as_f64()
            .unwrap()
            > 0.0
    );
    // every trace line is one well-formed event object
    let text = std::fs::read_to_string(&trace).unwrap();
    let mut spans = 0usize;
    for line in text.lines() {
        let ev = Json::parse(line).unwrap();
        if ev.at("ev").as_str() == Some("span") {
            spans += 1;
        }
    }
    assert!(spans > 0, "no span events in the trace");
    let _ = std::fs::remove_file(&trace);
}

#[test]
fn worker_contention_telemetry_is_execution_only() {
    let Some(d) = dir("malnet_sage_n128") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let eng = Engine::open(&d).unwrap();
    let data = MalnetDataset::generate(MalnetSplit::Tiny, 40, 3);
    // worker attribution, the imbalance gauge, timed locks and the
    // lock-wait heartbeat all ride the parallel path: the telemetry run
    // uses 4 workers + a fill cache and must still train the exact
    // parameters of the silent single-worker run
    let run = |workers: usize, record: bool| {
        let mut c = cfg(Method::GstED, workers);
        c.fill_cache_mb = 16;
        c.obs = ObsConfig { record, ..ObsConfig::default() };
        let mut tr = MalnetTrainer::new(&eng, &data, c).unwrap();
        let res = tr.train().unwrap();
        (tr.ps.values.clone(), tr.ps.m.clone(), res)
    };
    let (p0, m0, _) = run(1, false);
    let (p4, m4, r4) = run(4, true);
    assert_eq!(p0, p4, "parameters diverge with telemetry + workers");
    assert_eq!(m0, m4, "Adam moments diverge with telemetry + workers");

    // the v3 report carries populated worker + contention sections
    let rep = &r4.report;
    let workers = rep.at("workers");
    assert_eq!(workers.at("count").as_f64(), Some(4.0));
    assert!(workers.at("fork_joins").as_f64().unwrap() > 0.0);
    assert_eq!(workers.at("busy_ms").as_arr().unwrap().len(), 4);
    let imb = workers.at("imbalance_pct").as_f64().unwrap();
    assert!((0.0..=100.0).contains(&imb), "imbalance {imb}");
    let contention = rep.at("contention");
    let locks = contention.at("locks").as_obj().unwrap();
    for key in ["engine.exes", "engine.calls", "engine.param_lits"] {
        assert!(locks.contains_key(key), "missing lock `{key}`");
    }
    assert!(
        locks["engine.calls"].at("acquisitions").as_f64().unwrap()
            > 0.0
    );
    assert!(
        locks["task.fill_cache"].at("acquisitions").as_f64().unwrap()
            > 0.0
    );
    assert!(contention.at("total_wait_ms").as_f64().unwrap() >= 0.0);
    assert!(
        contention.at("table_writeback_ms").as_f64().unwrap() > 0.0
    );
    // v3: lock waits split by the waiter's phase — all 9 slots present
    // and reconciling with the total
    let by_phase = contention.at("by_phase").as_obj().unwrap();
    let mut split_sum = 0.0;
    for key in [
        "step", "sample", "fill", "embed_fwd", "grad", "table_commit",
        "eval", "finetune", "untagged",
    ] {
        let ms = by_phase
            .get(key)
            .unwrap_or_else(|| panic!("missing by_phase slot `{key}`"))
            .as_f64()
            .unwrap();
        assert!(ms >= 0.0);
        split_sum += ms;
    }
    let total = contention.at("total_wait_ms").as_f64().unwrap();
    assert!(
        (split_sum - total).abs() < 1e-6,
        "by_phase sums to {split_sum}, total_wait_ms {total}"
    );

    // the analytics layer consumes the real report end-to-end: the
    // reader accepts it and a self-diff reports zero regressions
    let analysis = analyze::analyze_report(rep).unwrap();
    assert_eq!(
        analysis.at("source_schema").as_str(),
        Some("gst-run-report/v3")
    );
    let diff = analyze::diff_reports(rep, rep, 20.0).unwrap();
    assert_eq!(diff.at("pass").as_bool(), Some(true));
}

#[test]
fn steady_state_knobs_never_change_parameters() {
    let Some(d) = dir("malnet_sage_n128") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let eng = Engine::open(&d).unwrap();
    let data = MalnetDataset::generate(MalnetSplit::Tiny, 40, 3);
    // the whole allocation-free machinery — reused step plans, the
    // shared generation-keyed fill cache, batched table write-backs —
    // is execution-only: every (workers, shared_cache, batched) corner
    // trains bit-identical parameters over a multi-epoch run, and the
    // post-warmup epochs never grow a plan buffer
    let run = |workers: usize, shared: bool, batched: bool| {
        let mut c = cfg(Method::GstEFD, workers);
        c.epochs = 3;
        c.fill_cache_mb = 16;
        c.shared_fill_cache = shared;
        c.batched_writeback = batched;
        let mut tr = MalnetTrainer::new(&eng, &data, c).unwrap();
        let res = tr.train().unwrap();
        assert_eq!(
            tr.steady_plan_reallocs(),
            0,
            "steady-state plan pool grew \
             (workers={workers}, shared={shared}, batched={batched})"
        );
        (tr.ps.values.clone(), tr.ps.m.clone(), tr.ps.v.clone(), res)
    };
    let (p0, m0, v0, r0) = run(1, true, true);
    for (workers, shared, batched) in
        [(4, true, true), (1, false, true), (1, true, false), (4, false, false)]
    {
        let (p, m, v, r) = run(workers, shared, batched);
        let tag = format!(
            "workers={workers}, shared={shared}, batched={batched}"
        );
        assert_eq!(p0, p, "parameters diverge ({tag})");
        assert_eq!(m0, m, "Adam m moments diverge ({tag})");
        assert_eq!(v0, v, "Adam v moments diverge ({tag})");
        assert_eq!(r0.test_metric, r.test_metric, "{tag}");
    }
}
