"""Model/variant configuration shared by the L2 model code and the AOT driver.

A *variant* is one fully-specified compilation target: (dataset, backbone,
segment size, batch size, hidden dims, optimizer constants). Each variant
produces one artifact directory ``artifacts/<variant>/`` with the lowered HLO
functions, a ``manifest.json`` describing every input/output/parameter, and
``init_params.bin`` with deterministic initial weights.

The rust L3 coordinator is entirely manifest-driven: nothing here is
duplicated as a rust-side constant.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class OptConfig:
    """Adam + L2 weight-decay constants (paper App. B)."""

    lr: float = 0.001
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 1e-4
    # Learning rate used during the prediction-head finetuning phase (+F).
    head_lr: float = 0.001


@dataclass(frozen=True)
class VariantConfig:
    """One AOT compilation target."""

    dataset: str  # "malnet" | "tpu"
    backbone: str  # "gcn" | "sage" | "gps"
    max_nodes: int = 128  # N: padded segment size (paper: m_GST)
    batch: int = 8  # B: graphs (segments) per training step
    feat: int = 16  # F: input node feature dim
    hidden: int = 64  # H: hidden / embedding dim
    classes: int = 5  # C: output classes (malnet only)
    mp_layers: int = 2  # message-passing layers (3 for gps, per paper tbl. 5)
    opt: OptConfig = field(default_factory=OptConfig)

    @property
    def name(self) -> str:
        return f"{self.dataset}_{self.backbone}_n{self.max_nodes}"

    @property
    def adj_norm(self) -> str:
        """Which normalized adjacency L3 must materialize per segment.

        gcn  -> D^-1/2 (A+I) D^-1/2   (symmetric, self loops)
        sage/gps -> D^-1 A            (row mean, no self loops; the self
                                       contribution is the separate W_self)
        """
        return "sym_selfloop" if self.backbone == "gcn" else "row_mean"

    def to_json_dict(self):
        d = asdict(self)
        d["name"] = self.name
        d["adj_norm"] = self.adj_norm
        return d


def default_variants():
    """The artifact set built by ``make artifacts``.

    - malnet x {gcn, sage, gps} at N=128 (Tables 1, 3, 6; Figs 2, 3, 6)
    - tpu x sage at N=128 (Table 2, Fig 5)
    - malnet x sage at N in {32, 64, 256} (Fig 4 segment-size ablation)
    """
    variants = [
        VariantConfig("malnet", "gcn"),
        VariantConfig("malnet", "sage"),
        VariantConfig("malnet", "gps", mp_layers=3),
        VariantConfig("tpu", "sage", feat=24, mp_layers=4,
                      opt=OptConfig(lr=1e-4)),
        VariantConfig("malnet", "sage", max_nodes=32),
        VariantConfig("malnet", "sage", max_nodes=64),
        VariantConfig("malnet", "sage", max_nodes=256),
    ]
    return variants
