"""L1 kernel correctness: pallas vs pure-jnp oracle, hypothesis-swept.

This is the core correctness signal for the compute layer: every kernel is
checked forward AND backward (via the custom_vjp) against kernels/ref.py
across randomized shapes, activation tags, masks and magnitudes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

SET = settings(max_examples=20, deadline=None)


def _arr(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(scale=scale, size=shape).astype(np.float32))


# -- matmul_bias_act --------------------------------------------------------

@SET
@given(
    m=st.sampled_from([1, 8, 64, 128, 256, 384]),
    k=st.sampled_from([1, 16, 64, 256]),
    n=st.sampled_from([1, 5, 64, 128, 256]),
    act=st.sampled_from(["none", "relu", "prelu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_forward(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w = _arr(rng, (m, k)), _arr(rng, (k, n))
    b, a = _arr(rng, (n,)), jnp.asarray([0.25], jnp.float32)
    got = kernels.matmul_bias_act(x, w, b, a, act)
    want = ref.matmul_bias_act_ref(x, w, b, a, act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@SET
@given(
    m=st.sampled_from([8, 128, 256]),
    k=st.sampled_from([16, 64]),
    n=st.sampled_from([5, 64, 128]),
    act=st.sampled_from(["none", "relu", "prelu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_gradients(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    args = (_arr(rng, (m, k)), _arr(rng, (k, n)), _arr(rng, (n,)),
            jnp.asarray([0.25], jnp.float32))

    def lk(t):
        return jnp.sum(jnp.sin(kernels.matmul_bias_act(*t, act)))

    def lr(t):
        return jnp.sum(jnp.sin(ref.matmul_bias_act_ref(*t, act)))

    for got, want in zip(jax.grad(lk)(args), jax.grad(lr)(args)):
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_matmul_relu_clamps_negative():
    x = jnp.asarray([[-1.0, 1.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    out = kernels.matmul_bias_act(x, w, jnp.zeros(2), jnp.zeros(1), "relu")
    assert float(out[0, 0]) == 0.0 and float(out[0, 1]) == 1.0


def test_matmul_prelu_uses_alpha():
    x = jnp.asarray([[-2.0]], jnp.float32)
    w = jnp.ones((1, 1), jnp.float32)
    out = kernels.matmul_bias_act(
        x, w, jnp.zeros(1), jnp.asarray([0.5], jnp.float32), "prelu")
    assert float(out[0, 0]) == pytest.approx(-1.0)


def test_matmul_shape_mismatch_raises():
    with pytest.raises(AssertionError):
        kernels.matmul_bias_act(
            jnp.zeros((4, 3)), jnp.zeros((2, 5)), jnp.zeros(5),
            jnp.zeros(1), "none")


# -- adj_matmul (message passing) ------------------------------------------

@SET
@given(
    bsz=st.sampled_from([1, 3, 8]),
    n=st.sampled_from([1, 16, 64, 128, 256]),
    f=st.sampled_from([1, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_adj_matmul_forward(bsz, n, f, seed):
    rng = np.random.default_rng(seed)
    adj, x = _arr(rng, (bsz, n, n)), _arr(rng, (bsz, n, f))
    np.testing.assert_allclose(
        kernels.adj_matmul(adj, x), ref.adj_matmul_ref(adj, x),
        rtol=1e-4, atol=1e-4)


@SET
@given(
    bsz=st.sampled_from([1, 4]),
    n=st.sampled_from([16, 128]),
    f=st.sampled_from([8, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_adj_matmul_feature_gradients(bsz, n, f, seed):
    """d(x) must match the reference; d(adj) is zero BY CONTRACT — the
    adjacency is data in GST, and computing its true cotangent costs an
    un-DCE-able matmul inside the interpret-mode while loop (§Perf L2)."""
    rng = np.random.default_rng(seed)
    adj, x = _arr(rng, (bsz, n, n), 0.3), _arr(rng, (bsz, n, f), 0.3)
    gx = jax.grad(lambda t: jnp.sum(jnp.tanh(kernels.adj_matmul(adj, t))))(x)
    rx = jax.grad(lambda t: jnp.sum(jnp.tanh(ref.adj_matmul_ref(adj, t))))(x)
    np.testing.assert_allclose(gx, rx, rtol=2e-3, atol=2e-3)
    gadj = jax.grad(
        lambda a: jnp.sum(jnp.tanh(kernels.adj_matmul(a, x))))(adj)
    assert float(jnp.abs(gadj).max()) == 0.0


def test_adj_matmul_zero_adjacency_is_zero():
    out = kernels.adj_matmul(jnp.zeros((2, 8, 8)), jnp.ones((2, 8, 4)))
    assert float(jnp.abs(out).max()) == 0.0


def test_adj_matmul_identity_preserves_features():
    x = jnp.arange(32, dtype=jnp.float32).reshape(1, 8, 4)
    eye = jnp.eye(8, dtype=jnp.float32)[None]
    np.testing.assert_allclose(kernels.adj_matmul(eye, x), x, rtol=1e-6)


# -- linear attention --------------------------------------------------------

@SET
@given(
    bsz=st.sampled_from([1, 2, 8]),
    n=st.sampled_from([4, 64, 128]),
    h=st.sampled_from([8, 32, 64]),
    frac=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_linattn_forward(bsz, n, h, frac, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (_arr(rng, (bsz, n, h)) for _ in range(3))
    mask = jnp.asarray(
        (rng.uniform(size=(bsz, n)) < frac).astype(np.float32))
    np.testing.assert_allclose(
        kernels.linear_attention(q, k, v, mask),
        ref.linear_attention_ref(q, k, v, mask), rtol=1e-4, atol=1e-4)


@SET
@given(
    bsz=st.sampled_from([1, 2]),
    n=st.sampled_from([16, 64]),
    h=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_linattn_gradients(bsz, n, h, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (_arr(rng, (bsz, n, h)) for _ in range(3))
    mask = jnp.asarray(rng.integers(0, 2, (bsz, n)).astype(np.float32))
    gk = jax.grad(
        lambda t: jnp.sum(jnp.tanh(kernels.linear_attention(*t, mask))))(
            (q, k, v))
    gr = jax.grad(
        lambda t: jnp.sum(jnp.tanh(ref.linear_attention_ref(*t, mask))))(
            (q, k, v))
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(got, want, rtol=3e-3, atol=3e-3)


def test_linattn_masked_keys_do_not_contribute():
    """Changing k/v at masked positions must not change the output."""
    rng = np.random.default_rng(7)
    q, k, v = (_arr(rng, (1, 16, 8)) for _ in range(3))
    mask = jnp.asarray([[1.0] * 8 + [0.0] * 8])
    out1 = kernels.linear_attention(q, k, v, mask)
    k2 = k.at[:, 8:].set(99.0)
    v2 = v.at[:, 8:].set(-99.0)
    out2 = kernels.linear_attention(q, k2, v2, mask)
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)


def test_linattn_rows_are_convex_mixtures():
    """With phi >= 0, each output row is a weighted average of values."""
    rng = np.random.default_rng(3)
    q, k = (_arr(rng, (1, 32, 8)) for _ in range(2))
    v = jnp.asarray(rng.uniform(2.0, 3.0, (1, 32, 8)).astype(np.float32))
    mask = jnp.ones((1, 32), jnp.float32)
    out = kernels.linear_attention(q, k, v, mask)
    assert float(out.min()) >= 1.9 and float(out.max()) <= 3.1


# -- analytic perf model sanity ---------------------------------------------

def test_vmem_models_fit_budget():
    """Every BlockSpec this model family emits must fit TPU VMEM (16 MiB)."""
    from compile.kernels import attention, matmul, spmm
    budget = 16 * 1024 * 1024
    for (m, k, n) in [(1024, 256, 128), (2048, 64, 128), (128, 64, 5)]:
        assert matmul.vmem_bytes(m, k, n) < budget
    for (n, f) in [(128, 64), (256, 64), (512, 128)]:
        assert spmm.vmem_bytes(n, f) < budget
    for (n, h) in [(128, 64), (256, 64)]:
        assert attention.vmem_bytes(n, h) < budget


def test_mxu_utilization_bounds():
    from compile.kernels import matmul, spmm
    for (m, k, n) in [(1024, 64, 128), (128, 64, 64)]:
        u = matmul.mxu_utilization(m, k, n)
        assert 0.0 < u <= 1.0
    assert spmm.mxu_utilization(128, 64) == pytest.approx(0.5)
