//! MalNet-Large classification — the paper's headline scenario: graphs too
//! large for full-graph training, compared across training methods.
//!
//!     cargo run --release --example malnet_classification
//!
//! Expected shape (Table 1): FullGraph OOMs; GST trains well but slowly;
//! GST+E collapses from staleness; GST+EFD recovers and is ~3x faster
//! than GST per step.

use gst::datasets::{MalnetDataset, MalnetSplit};
use gst::runtime::Engine;
use gst::train::{MalnetTrainer, Method, TrainConfig};

fn main() -> anyhow::Result<()> {
    let eng = Engine::open("artifacts/malnet_sage_n128")?;
    let data = MalnetDataset::generate(MalnetSplit::Large, 30, 7);
    println!(
        "MalNet-Large analogue: {} graphs (avg {:.0} nodes)",
        data.graphs.len(),
        data.graphs.iter().map(|g| g.num_nodes()).sum::<usize>() as f64
            / data.graphs.len() as f64
    );
    println!(
        "\n{:<22} {:>9} {:>9} {:>10}  note",
        "method", "train", "test", "ms/step"
    );
    for method in [
        Method::FullGraph,
        Method::Gst,
        Method::GstOne,
        Method::GstE,
        Method::GstEFD,
    ] {
        let cfg = TrainConfig {
            method,
            epochs: 8,
            finetune_epochs: 3,
            eval_every: 8,
            seed: 7,
            ..TrainConfig::default()
        };
        match MalnetTrainer::new(&eng, &data, cfg) {
            Err(e) if e.to_string().contains("OOM") => {
                println!("{:<22} {:>9} {:>9} {:>10}  {}", method.name(),
                         "OOM", "OOM", "-", "exceeds memory budget");
            }
            Err(e) => return Err(e),
            Ok(mut tr) => {
                let res = tr.train()?;
                println!(
                    "{:<22} {:>9.3} {:>9.3} {:>10.1}",
                    method.name(), res.train_metric, res.test_metric,
                    res.step_ms
                );
            }
        }
    }
    Ok(())
}
