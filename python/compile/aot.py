"""AOT driver: lower every variant's function set to XLA HLO *text*.

Interchange rule (see /opt/xla-example/README.md): jax >= 0.5 serializes
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust ``xla`` crate) rejects; the HLO *text* parser
reassigns ids and round-trips cleanly. So: ``.lower() -> stablehlo ->
XlaComputation -> as_hlo_text()`` — never ``.serialize()``.

Per variant this writes::

    artifacts/<variant>/
        manifest.json        # dims, adj_norm, optimizer, params, functions
        init_params.bin      # f32 LE, name-sorted order (seed 0)
        <fn>.hlo.txt         # one per AOT function

``--check`` additionally executes each lowered module via jax on dummy
inputs and compares against the un-lowered python function (a full
round-trip guard run by pytest).

Usage: python -m compile.aot [--out DIR] [--variant NAME ...] [--check]
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .config import VariantConfig, default_variants

_DTYPES = {"f32": jnp.float32, "s32": jnp.int32}


def spec_to_jax(spec):
    return jax.ShapeDtypeStruct(tuple(spec["shape"]), _DTYPES[spec["dtype"]])


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, in_specs) -> str:
    args = [spec_to_jax(s) for s in in_specs]
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*args))


def dummy_inputs(in_specs, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for s in in_specs:
        shape = tuple(s["shape"])
        if s["dtype"] == "s32":
            out.append(rng.integers(0, 2, size=shape).astype(np.int32))
        else:
            out.append(rng.normal(scale=0.1, size=shape).astype(np.float32))
    return out


def build_variant(cfg: VariantConfig, out_root: str, check: bool = False):
    params = model.init_params(cfg, seed=0)
    names = model.param_order(params)
    fns = model.function_set(cfg, params)
    vdir = os.path.join(out_root, cfg.name)
    os.makedirs(vdir, exist_ok=True)

    manifest = {
        "variant": cfg.to_json_dict(),
        "full_jmax": model.FULL_JMAX,
        "table_dim": cfg.hidden if cfg.dataset == "malnet" else 1,
        "params": [
            {
                "name": k,
                "shape": list(params[k].shape),
                "dtype": "f32",
                "head": k in model.head_param_names(cfg, params),
            }
            for k in names
        ],
        "functions": {},
    }

    blob = b"".join(params[k].tobytes() for k in names)
    with open(os.path.join(vdir, "init_params.bin"), "wb") as f:
        f.write(blob)

    for fname, (fn, in_specs, out_specs) in fns.items():
        text = lower_fn(fn, in_specs)
        path = os.path.join(vdir, f"{fname}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["functions"][fname] = {
            "file": f"{fname}.hlo.txt",
            "inputs": in_specs,
            "outputs": out_specs,
        }
        if check:
            _roundtrip_check(fn, in_specs, out_specs, text, fname)
        print(f"  {cfg.name}/{fname}: {len(in_specs)} in / "
              f"{len(out_specs)} out / {len(text)//1024} KiB HLO")

    with open(os.path.join(vdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return manifest


def _roundtrip_check(fn, in_specs, out_specs, hlo_text, fname):
    """Validate the lowered artifact: the HLO text must be well-formed and
    the compiled (jit) execution must match the eager python function on
    random inputs. Loading the *text* through PJRT is covered by the rust
    integration tests (rust/tests/runtime_roundtrip.rs), which execute the
    same files against these semantics."""
    assert hlo_text.startswith("HloModule"), fname
    assert "ENTRY" in hlo_text, fname
    args = dummy_inputs(in_specs)
    expect = fn(*args)
    got = jax.jit(fn)(*args)
    assert len(got) == len(expect) == len(out_specs), fname
    for g, e, s in zip(got, expect, out_specs):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(e), rtol=2e-4, atol=2e-4,
            err_msg=f"{fname}:{s['name']}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--variant", action="append", default=None,
                    help="variant name filter (default: all)")
    ap.add_argument("--check", action="store_true",
                    help="execute lowered HLO and compare vs eager python")
    args = ap.parse_args(argv)

    variants = default_variants()
    if args.variant:
        variants = [v for v in variants if v.name in set(args.variant)]
        if not variants:
            sys.exit(f"no variant matches {args.variant}")
    for cfg in variants:
        print(f"[aot] building {cfg.name}")
        build_variant(cfg, args.out, check=args.check)
    print(f"[aot] done: {len(variants)} variants -> {args.out}")


if __name__ == "__main__":
    main()
