//! Fixed-bucket histogram for staleness and latency distributions.

use crate::util::json::Json;

/// Fixed-bucket histogram: `edges` are strictly-ascending **inclusive**
/// upper bounds; the counts vector carries one extra overflow bucket at
/// the end, so `counts.len() == edges.len() + 1`.
#[derive(Clone, Debug)]
pub struct Histogram {
    edges: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    pub fn new(edges: Vec<f64>) -> Histogram {
        assert!(
            edges.windows(2).all(|w| w[0] < w[1]),
            "histogram edges must be strictly ascending"
        );
        let n = edges.len() + 1;
        Histogram {
            edges,
            counts: vec![0; n],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Power-of-two staleness buckets in optimizer steps:
    /// ≤0, ≤1, ≤2, ≤4, …, ≤4096, then overflow.
    pub fn staleness() -> Histogram {
        let mut edges = vec![0.0];
        let mut e = 1.0;
        while e <= 4096.0 {
            edges.push(e);
            e *= 2.0;
        }
        Histogram::new(edges)
    }

    pub fn observe(&mut self, v: f64) {
        let i = self.edges.partition_point(|&e| e < v);
        self.counts[i] += 1;
        self.total += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "edges",
                Json::arr(self.edges.iter().map(|&e| Json::num(e))),
            ),
            (
                "counts",
                Json::arr(
                    self.counts.iter().map(|&c| Json::num(c as f64)),
                ),
            ),
            ("count", Json::num(self.total as f64)),
            ("mean", Json::num(self.mean())),
            ("max", Json::num(self.max())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_use_inclusive_upper_bounds() {
        let mut h = Histogram::new(vec![1.0, 2.0, 4.0]);
        h.observe(0.5); // -> bucket 0
        h.observe(1.0); // inclusive upper bound -> bucket 0
        h.observe(1.5); // -> bucket 1
        h.observe(4.0); // -> bucket 2
        h.observe(9.0); // -> overflow
        assert_eq!(h.counts(), &[2, 1, 1, 1]);
        assert_eq!(h.count(), 5);
        assert!((h.mean() - 16.0 / 5.0).abs() < 1e-12);
        assert_eq!(h.min(), 0.5);
        assert_eq!(h.max(), 9.0);
    }

    #[test]
    fn empty_histogram_reports_zeroes() {
        let h = Histogram::new(vec![1.0]);
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.to_json().at("count").as_f64(), Some(0.0));
    }

    #[test]
    fn staleness_buckets_cover_powers_of_two() {
        let h = Histogram::staleness();
        // edges 0, 1, 2, 4, ..., 4096 -> 14 edges, 15 buckets
        let j = h.to_json();
        assert_eq!(j.at("edges").as_arr().unwrap().len(), 14);
        assert_eq!(j.at("counts").as_arr().unwrap().len(), 15);
        let mut h = h;
        h.observe(3.0);
        h.observe(5000.0);
        // 3 lands in the ≤4 bucket (index 3), 5000 overflows
        assert_eq!(h.counts()[3], 1);
        assert_eq!(h.counts()[14], 1);
    }
}
