"""L1 Pallas kernel: batched segment message passing  out[b] = adj[b] @ x[b].

This is the GST hardware adaptation in one kernel (DESIGN.md
section Hardware-Adaptation): the paper's V100 implementation does edge-list
gather + scatter-add with warp atomics; on TPU we *densify the per-segment
normalized adjacency* and run it through the MXU. GST's bounded segment size
(N <= 256 here) is exactly what makes this legal — an N x N f32 tile is at
most 256 KiB, far under VMEM — and it converts irregular scatter traffic into
a systolic matmul at full MXU occupancy.

Schedule: grid over (segment b, row-block i). Each step stages
  adj tile (1, bm, N)  +  x panel (1, N, F)  ->  out tile (1, bm, F)
HBM->VMEM; the x panel index map ignores i, so consecutive row-blocks of the
same segment reuse the resident panel (pipelined double-buffering on TPU).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 128


def _adj_mm_kernel(adj_ref, x_ref, o_ref):
    o_ref[0, ...] = jnp.dot(
        adj_ref[0, ...], x_ref[0, ...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def bmm(a, b):
    """Batched pallas matmul ``out[i] = a[i] @ b[i]`` — the shared schedule
    behind the forward message passing and both of its backward products."""
    bsz, m, k = a.shape
    bsz2, k2, n = b.shape
    assert bsz == bsz2 and k == k2, (a.shape, b.shape)
    bm = BLOCK_ROWS if m % BLOCK_ROWS == 0 else m
    grid = (bsz, m // bm)
    return pl.pallas_call(
        _adj_mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, k), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, k, n), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, n), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, m, n), jnp.float32),
        interpret=True,
    )(a, b)


# Reverse-mode rule: pallas_call has none in interpret mode, and message
# passing sits inside every backbone layer, so the VJP is spelled out with
# the same bmm schedule:  d(adj) = g x^T,  d(x) = adj^T g.
@jax.custom_vjp
def adj_matmul(adj, x):
    """Batched dense message passing.

    adj: (B, N, N) f32 — normalized segment adjacency (zero rows/cols on pad)
    x:   (B, N, F) f32 — node features
    returns (B, N, F) f32.
    """
    bsz, n, n2 = adj.shape
    bsz2, n3, f = x.shape
    assert n == n2 == n3 and bsz == bsz2, (adj.shape, x.shape)
    return bmm(adj, x)


def _adj_fwd(adj, x):
    return adj_matmul(adj, x), (adj, x)


def _adj_bwd(res, g):
    adj, x = res
    # Contract: the adjacency is *data* in GST (a normalized topology
    # constant), never a trained quantity, so its cotangent is defined as
    # zero. Computing the true d(adj) = g x^T would bury an extra N x N
    # matmul inside an opaque interpret-mode while loop that XLA cannot
    # DCE — measured at ~25% of grad_step's dots (EXPERIMENTS.md §Perf L2).
    dadj = jnp.zeros_like(adj)
    dx = bmm(jnp.swapaxes(adj, 1, 2), g)
    return dadj, dx


adj_matmul.defvjp(_adj_fwd, _adj_bwd)


def vmem_bytes(n: int, f: int) -> int:
    """Resident VMEM for one grid step (adj tile + x panel + out tile)."""
    bm = BLOCK_ROWS if n % BLOCK_ROWS == 0 else n
    return 4 * (bm * n + n * f + bm * f)


def mxu_utilization(n: int, f: int) -> float:
    """MACs used / MACs offered; F < 128 under-fills MXU columns, which is
    the known cost of the densify strategy at small hidden dims."""
    bm = BLOCK_ROWS if n % BLOCK_ROWS == 0 else n
    ceil = lambda a, q: -(-a // q)
    passes = ceil(bm, 128) * ceil(f, 128) * ceil(n, 128)
    return (bm * f * n) / (passes * 128 * 128 * 128)
