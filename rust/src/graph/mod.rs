//! Graph substrate: CSR storage, builders, statistics and binary I/O.
//!
//! Everything downstream (partitioners, segment extraction, generators)
//! works on [`Csr`] — an undirected graph in compressed-sparse-row form
//! with per-node f32 feature vectors.

pub mod csr;
pub mod io;
pub mod stats;

pub use csr::{Csr, GraphBuilder};
pub use stats::GraphStats;
