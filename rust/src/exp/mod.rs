//! Experiment drivers — one per table/figure in the paper's evaluation
//! (the DESIGN.md §5 index). Invoked via `gst experiment --id <name>`.

pub mod common;
pub mod figs;
pub mod scaling;
pub mod tables;

use anyhow::{bail, Result};
use common::Env;

pub const ALL_IDS: [&str; 11] = [
    "table1", "table2", "table3", "table4", "table6", "fig2", "fig3",
    "fig4", "fig5", "fig6", "scaling",
];

/// Run one experiment by id.
pub fn run(id: &str, env: &Env) -> Result<()> {
    match id {
        "table1" => tables::table1(env),
        "table2" => tables::table2(env),
        "table3" => tables::table3(env),
        "table4" => tables::table4(env),
        "table6" => tables::table6(env),
        "fig2" => figs::fig2(env),
        "fig3" => figs::fig3(env),
        "fig4" => figs::fig4(env),
        "fig5" => figs::fig5(env),
        "fig6" => figs::fig6(env),
        "scaling" => scaling::scaling(env),
        other => bail!("unknown experiment `{other}`; known: {ALL_IDS:?}"),
    }
}
