//! The dataset-agnostic GST core: one implementation of the paper's
//! Algorithm 1/2 inner loop shared by every task (DESIGN.md §4).
//!
//! [`GstTask`] is the thin, dataset-specific surface — segment provider,
//! per-slot batch fill, historical-table row mapping, loss-specific
//! buffers, eval hook — while [`GstCore`] owns everything the methods have
//! in common: epoch shuffling, segment sampling, the SED draw, historical
//! table reads / fresh recomputation / write-back, micro-batch gradient
//! averaging, step timing, eval cadence and the +F finetuning phase.
//!
//! # Execution model
//!
//! Each optimization step processes `cfg.micro_batches` micro-batches
//! (simulated data-parallel devices, gradients averaged before one Adam
//! apply) in three phases:
//!
//! 1. **plan** (sequential) — per micro-batch: derive a private RNG stream
//!    keyed by the global step index, let the task describe the batch
//!    slots, sample segments, draw SED weights, and resolve stale reads
//!    against a *snapshot* of the table (the state at group start);
//! 2. **compute** (parallel) — the micro-batches are sharded contiguously
//!    over `cfg.workers` threads via [`fork_join_with`]; each worker owns
//!    a reusable [`BatchBufs`] (staging `embed_fwd` batches and the grad
//!    batch in turn) and drives the shared [`Engine`] (which is `Sync`);
//! 3. **commit** (sequential, in micro-batch order) — table write-backs
//!    (Alg. 2 line 7), gradient averaging, one optimizer apply.
//!
//! Because plans depend only on the step index and the group-start table
//! snapshot, and commits replay in micro-batch order, **the trained
//! parameters are identical for any `cfg.workers` value** — threads are an
//! execution knob, `micro_batches` is the semantic one. The conformance
//! suite pins this (workers=1 vs workers=4, same parameters).

use super::ops::{self, BatchBufs, GradAccum};
use super::{Method, RunResult, SedMode, TrainConfig};
use crate::memory::MemoryModel;
use crate::metrics::{CacheStats, Curve};
use crate::obs::{EpochStats, Histogram, Phase, Recorder};
use crate::runtime::{Engine, Manifest, ParamStore};
use crate::sed;
use crate::table::EmbeddingTable;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::sync::LockStats;
use crate::util::threads;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::time::Instant;

/// One micro-batch slot, described by the task during the plan phase.
#[derive(Clone, Debug)]
pub struct SlotSpec {
    /// Historical-table row backing this slot (graph, or (graph, config)).
    pub row: usize,
    /// Number of segments J of the slot's parent graph.
    pub num_segments: usize,
    /// Pooling normalization fed to `grad_step`: 1/J (mean pool, MalNet)
    /// or 1.0 (sum pool, TpuGraphs §5.3).
    pub invj: f32,
}

/// Mutable views of the core-owned training state, handed to task hooks
/// that run outside the shared inner loop (FullGraph baseline epochs and
/// the +F finetuning phase).
pub struct CoreEnv<'e> {
    pub eng: &'e Engine,
    pub cfg: &'e TrainConfig,
    pub ps: &'e mut ParamStore,
    pub table: &'e mut EmbeddingTable,
    pub rng: &'e mut Pcg64,
    /// run-wide recorder (step timing, spans, counters — all `&self`)
    pub obs: &'e Recorder,
    pub step: &'e mut u32,
    /// shared in-place gradient reducer (core-owned, reused every group)
    pub accum: &'e mut GradAccum,
}

/// Effective learning rate: config override or the manifest default —
/// the single definition shared by the inner loop, the FullGraph
/// baseline and the finetune phase.
pub fn effective_lr(cfg: &TrainConfig, eng: &Engine) -> f32 {
    cfg.lr.unwrap_or(eng.manifest.lr)
}

impl CoreEnv<'_> {
    /// Effective learning rate (config override or manifest default).
    pub fn lr(&self) -> f32 {
        effective_lr(self.cfg, self.eng)
    }
}

/// Dataset-specific surface of the GST trainer. Implementations are thin
/// (~100 lines): everything method-shaped lives in [`GstCore`].
///
/// `Sync` is required because `fill_slot`/`fill_loss` run concurrently on
/// worker threads during the compute phase (read-only).
pub trait GstTask: Sync {
    /// Per-micro-batch state threaded from [`GstTask::begin_step`] into
    /// the fill callbacks (slot → graph/config resolution, cached
    /// features). Shared read-only across worker threads.
    type StepCtx: Send + Sync;

    /// Manifest `dataset` this task drives (sanity-checked at startup).
    fn dataset(&self) -> &'static str;

    /// RNG stream tag keeping task families decorrelated across datasets.
    fn seed_tag(&self) -> u64;

    /// AOT functions to pre-compile for `method`, so step timings
    /// (Table 3) exclude compilation.
    fn warmup_fns(&self, method: Method) -> Vec<&'static str>;

    /// Historical-table layout: segments per row, in row order.
    fn table_rows(&self) -> Vec<usize>;

    /// Training items (dataset indices), shuffled once per epoch.
    fn train_items(&self) -> &[usize];

    /// Chunk one shuffled item order into micro-batch units (MalNet:
    /// chunks of B graphs, drop-last; TpuGraphs: one graph per unit).
    fn plan_epoch(&self, order: &[usize]) -> Vec<Vec<usize>>;

    /// Describe one micro-batch: build the per-step context and push
    /// exactly `manifest.batch` slot specs into `slots` (handed over
    /// cleared, with its allocation reused across steps). Runs
    /// sequentially in the plan phase; any task-side randomness (e.g.
    /// config sampling) draws from `rng`, the step's private stream.
    fn begin_step(
        &mut self,
        unit: &[usize],
        rng: &mut Pcg64,
        slots: &mut Vec<SlotSpec>,
    ) -> Self::StepCtx;

    /// Write the loss-specific buffers (`labels` for classification, the
    /// `pair` ordering mask for ranking; `pair` arrives zeroed).
    fn fill_loss(&self, ctx: &Self::StepCtx, bufs: &mut BatchBufs);

    /// Fill the padded (nodes, adj, mask) views with `slot`'s segment
    /// `seg`. Used for both the grad batch (sampled segments) and
    /// `embed_fwd` batches (stale-segment recomputation).
    fn fill_slot(
        &self,
        ctx: &Self::StepCtx,
        slot: usize,
        seg: usize,
        nodes: &mut [f32],
        adj: &mut [f32],
        mask: &mut [f32],
    );

    /// Scalar eval metric over dataset indices (accuracy / OPA).
    fn eval_metric(
        &self,
        eng: &Engine,
        ps: &ParamStore,
        items: &[usize],
    ) -> Result<f64>;

    /// Capped training subset used for the train-side curve points.
    fn eval_train_subset(&self) -> Vec<usize>;

    /// Test-set indices.
    fn test_items(&self) -> &[usize];

    /// Total segments across the dataset (observability).
    fn total_segments(&self) -> usize;

    /// Hit/miss counters of the task's padded fill-block cache, if it
    /// runs one (`cfg.fill_cache_mb`). Default: no cache.
    fn fill_cache_stats(&self) -> CacheStats {
        CacheStats::default()
    }

    /// Bytes held by the task's precomputed fill structures (telemetry
    /// gauge). Default: none.
    fn prepared_bytes(&self) -> usize {
        0
    }

    /// Bytes resident in the task's fill-block cache (telemetry gauge).
    /// Default: no cache.
    fn fill_cache_bytes(&self) -> usize {
        0
    }

    /// Contention counters of task-owned locks (name → stats), merged
    /// into the run report's `contention` section under a `task.`
    /// prefix. Default: no task-side locks.
    fn contention(&self) -> Vec<(String, LockStats)> {
        Vec::new()
    }

    /// Bind the fill-block cache generation (the parameter-store
    /// identity from `ParamStore::cache_key().0`) — called once by
    /// [`GstCore::with_task`] after parameters load, so cache entries
    /// are keyed to this trainer's store lifetime
    /// (`segment::FillHandle`). Default: no cache to bind.
    fn bind_fill_generation(&mut self, _gen: u64) {}

    /// Full Graph Training baseline epoch. Default: unsupported (tasks
    /// whose constructor rejects `Method::FullGraph` never reach this).
    fn full_graph_epoch(&mut self, _env: &mut CoreEnv<'_>) -> Result<()> {
        bail!(
            "Full Graph Training is not supported on {}",
            self.dataset()
        )
    }

    /// Prediction Head Finetuning (+F, Alg. 2 lines 11-18). Default:
    /// nothing to finetune (TpuGraphs: F' is a parameter-free sum).
    fn finetune(
        &mut self,
        _env: &mut CoreEnv<'_>,
        _curve: &mut Curve,
        _eval_train: &[usize],
    ) -> Result<()> {
        Ok(())
    }
}

/// Batch-padding rule shared by every `embed_fwd` batching site: a short
/// final chunk fills its trailing slots by repeating the chunk's **last**
/// entry (the repeats' embeddings are discarded, so any in-range segment
/// works; the last one keeps the rule branch-free).
pub fn padded_index(slot: usize, chunk_len: usize) -> usize {
    slot.min(chunk_len - 1)
}

/// SED weights for one slot under `mode` (Eq. 1 and its limiting
/// cases), drawn into the core's reusable scratch; returns `eta_fresh`.
fn sed_weights_into(
    mode: SedMode,
    j: usize,
    s: usize,
    rng: &mut Pcg64,
    eta_stale: &mut Vec<f32>,
) -> f32 {
    match mode {
        SedMode::KeepAll => sed::keep_all_into(j, &[s], eta_stale),
        SedMode::DropAll => sed::drop_all_into(j, &[s], eta_stale),
        SedMode::Draw(p) => sed::draw_into(j, &[s], p, rng, eta_stale),
    }
}

#[cfg(test)]
fn sed_weights(
    mode: SedMode,
    j: usize,
    s: usize,
    rng: &mut Pcg64,
) -> sed::SedWeights {
    let mut eta_stale = Vec::new();
    let eta_fresh = sed_weights_into(mode, j, s, rng, &mut eta_stale);
    sed::SedWeights { eta_fresh, eta_stale }
}

/// Fully-resolved plan for one micro-batch (plan phase output).
/// Immutable and `Sync` during the compute phase. The core owns one per
/// micro-batch slot and reset-and-reuses it every group, so the
/// steady-state plan phase performs no heap allocation (pinned by the
/// realloc counter the integration tests read).
struct StepPlan<C> {
    /// per-step task context, replaced by `reset` each group
    ctx: Option<C>,
    slots: Vec<SlotSpec>,
    /// sampled segment per slot
    sampled: Vec<usize>,
    /// SED fresh-segment weight per slot
    eta_fresh: Vec<f32>,
    /// [B, table_dim] stale aggregate, table hits pre-accumulated
    stale: Vec<f32>,
    /// stale segments to recompute fresh: (slot, seg, eta)
    fresh: Vec<(usize, usize, f32)>,
    /// global step index — the table write-back version
    step_id: u32,
}

impl<C> StepPlan<C> {
    /// A plan sized for `b` slots of dimension `td`; `fresh` holds the
    /// worst case (every slot recomputing all `Jmax - 1` stale
    /// segments) so it can never grow in steady state.
    fn with_capacity(b: usize, td: usize, fresh_cap: usize) -> StepPlan<C> {
        StepPlan {
            ctx: None,
            slots: Vec::with_capacity(b),
            sampled: vec![0; b],
            eta_fresh: vec![0.0; b],
            stale: vec![0.0; b * td],
            fresh: Vec::with_capacity(fresh_cap),
            step_id: 0,
        }
    }

    fn ctx(&self) -> &C {
        self.ctx.as_ref().expect("plan used before reset")
    }
}

/// Compute-phase output for one micro-batch. Core-owned and reused like
/// [`StepPlan`]: `out` is shaped once by [`ops::StepOut::zeros`] and
/// overwritten in place, `fresh_embs` is a flat `[nfresh, td]` arena.
struct StepResult {
    out: ops::StepOut,
    /// one embedding per `plan.fresh` entry, in order, flattened
    fresh_embs: Vec<f32>,
}

impl StepResult {
    fn with_capacity(m: &Manifest, fresh_cap: usize) -> StepResult {
        StepResult {
            out: ops::StepOut::zeros(m),
            fresh_embs: Vec::with_capacity(fresh_cap * m.table_dim),
        }
    }
}

/// Reusable scratch for batched table write-backs: collect each
/// micro-batch's (arena slot, arrival order) pairs, then [`flush`] them
/// as sorted maximal consecutive-slot runs — one staged
/// `copy_from_slice` per run instead of one `put` per row. Public (doc
/// hidden) so the steady-state bench can drive the exact committer the
/// trainer uses.
///
/// [`flush`]: CommitBatch::flush
#[doc(hidden)]
#[derive(Default)]
pub struct CommitBatch {
    /// (table arena slot, arrival order); the order index doubles as
    /// the payload id handed back to `flush`'s source lookup
    entries: Vec<(usize, u32)>,
    /// staged contiguous payload for the run being written
    staged: Vec<f32>,
}

impl CommitBatch {
    pub fn new() -> CommitBatch {
        CommitBatch::default()
    }

    /// Preallocate for `max_entries` write-backs of dimension `td`, so
    /// steady-state flushes never grow the scratch.
    pub fn with_capacity(max_entries: usize, td: usize) -> CommitBatch {
        CommitBatch {
            entries: Vec::with_capacity(max_entries),
            staged: Vec::with_capacity(max_entries * td),
        }
    }

    /// Start a new micro-batch's collection.
    pub fn begin(&mut self) {
        self.entries.clear();
    }

    /// Record a write-back of the next payload (payload ids are the
    /// 0-based push order) into table arena slot `table_slot`.
    pub fn push(&mut self, table_slot: usize) {
        let order = self.entries.len() as u32;
        self.entries.push((table_slot, order));
    }

    /// Write every collected entry into `table` at version `step`.
    /// `src(id)` returns payload `id`'s `td` floats.
    ///
    /// Ordering guarantee: for entries targeting the same slot, only
    /// the **last pushed** payload is written — exactly the sequential
    /// committer's outcome, where later `put`s overwrite earlier ones
    /// (sorting is by (slot, push order), so the keep-last dedup is a
    /// suffix pick within each equal-slot group).
    pub fn flush<'s, F>(
        &mut self,
        table: &mut EmbeddingTable,
        step: u32,
        src: F,
    ) where
        F: Fn(u32) -> &'s [f32],
    {
        self.entries.sort_unstable();
        // in-place keep-last dedup (sort_unstable + the compaction
        // below allocate nothing)
        let n = self.entries.len();
        let mut w = 0;
        for r in 0..n {
            if r + 1 == n || self.entries[r + 1].0 != self.entries[r].0 {
                self.entries[w] = self.entries[r];
                w += 1;
            }
        }
        self.entries.truncate(w);
        let mut i = 0;
        while i < self.entries.len() {
            let slot0 = self.entries[i].0;
            let mut j = i + 1;
            while j < self.entries.len()
                && self.entries[j].0 == slot0 + (j - i)
            {
                j += 1;
            }
            self.staged.clear();
            for e in &self.entries[i..j] {
                self.staged.extend_from_slice(src(e.1));
            }
            table.put_run(slot0, &self.staged, step);
            i = j;
        }
    }
}

/// The shared GST driver. Owns all cross-step state (parameters, Adam
/// moments, the historical table, RNG, timers); the task contributes only
/// dataset-specific mapping and filling.
pub struct GstCore<'a, T: GstTask> {
    pub task: T,
    eng: &'a Engine,
    pub cfg: TrainConfig,
    pub ps: ParamStore,
    pub table: EmbeddingTable,
    rng: Pcg64,
    step: u32,
    /// optimization steps recorded during epoch 0 (cold-table warmup)
    first_epoch_steps: usize,
    /// observability hub: always-on step timer + opt-in telemetry
    pub obs: Recorder,
    /// one reusable buffer set per worker (embed staging + grad batch)
    bufs: Vec<BatchBufs>,
    /// in-place gradient reducer, reused across every optimizer group
    accum: GradAccum,
    /// cumulative wall-clock of the serial table write-back loop (ns) —
    /// the commit path holds no lock (it has `&mut` on the table), so
    /// its cost is measured directly rather than through a timed lock
    table_writeback_ns: u64,
    /// reusable per-micro-batch plans (grown once, reset every group)
    plans: Vec<StepPlan<T::StepCtx>>,
    /// reusable per-micro-batch compute outputs, shard-aligned to plans
    results: Vec<StepResult>,
    /// reusable batched-write-back scratch (`cfg.batched_writeback`)
    commit: CommitBatch,
    /// reusable SED draw buffer (`sed::draw_into` target)
    sed_buf: Vec<f32>,
    /// worst-case `fresh` entries per micro-batch: B · (Jmax − 1)
    fresh_cap: usize,
    /// true once epoch 0 (cold table, pools warming) is behind us
    steady: bool,
    /// pool growth events while `steady` — must stay 0 (test hook)
    plan_reallocs: u64,
}

impl<'a, T: GstTask> GstCore<'a, T> {
    /// Wire a task to the shared driver: allocate the table from the
    /// task's row layout, load parameters, pre-compile the method's AOT
    /// functions, and size the per-worker buffer pool.
    pub fn with_task(
        eng: &'a Engine,
        mut task: T,
        cfg: TrainConfig,
    ) -> Result<GstCore<'a, T>> {
        assert_eq!(eng.manifest.dataset, task.dataset());
        assert_eq!(
            cfg.s_per_graph, 1,
            "the AOT grad_step samples S=1 segment per graph slot \
             (paper's setting)"
        );
        let rows = task.table_rows();
        let table = EmbeddingTable::new(&rows, eng.manifest.table_dim);
        let jmax = rows.iter().copied().max().unwrap_or(1);
        let fresh_cap =
            eng.manifest.batch * jmax.saturating_sub(1).max(1);
        let ps = ParamStore::load(eng.dir(), &eng.manifest)?;
        // key the task's fill cache to this store's lifetime
        task.bind_fill_generation(ps.cache_key().0);
        eng.warmup(&task.warmup_fns(cfg.method))?;
        let pool = cfg.workers.max(1).min(cfg.micro_batches.max(1));
        let bufs: Vec<BatchBufs> =
            (0..pool).map(|_| BatchBufs::new(&eng.manifest)).collect();
        let rng = Pcg64::new(cfg.seed, task.seed_tag());
        let obs = Recorder::new(&cfg.obs)?;
        if obs.is_enabled() {
            let m = &eng.manifest;
            let mm = MemoryModel::for_dataset(&m.dataset, &m.backbone);
            // segment edge counts are not manifest data; 4 × nodes is
            // the synthetic generators' average-degree envelope
            let peak = mm.gst_peak_bytes(
                m.batch,
                cfg.s_per_graph,
                m.max_nodes,
                4 * m.max_nodes,
            );
            obs.gauge("memory_model_peak_bytes", peak as f64);
            obs.gauge(
                "prepared_fill_bytes",
                task.prepared_bytes() as f64,
            );
            obs.gauge(
                "batch_bufs_bytes",
                bufs.iter().map(|b| b.bytes()).sum::<usize>() as f64,
            );
        }
        Ok(GstCore {
            task,
            eng,
            cfg,
            ps,
            table,
            rng,
            step: 0,
            first_epoch_steps: 0,
            obs,
            bufs,
            accum: GradAccum::new(&eng.manifest),
            table_writeback_ns: 0,
            plans: Vec::new(),
            results: Vec::new(),
            commit: CommitBatch::with_capacity(
                eng.manifest.batch + fresh_cap,
                eng.manifest.table_dim,
            ),
            sed_buf: Vec::new(),
            fresh_cap,
            steady: false,
            plan_reallocs: 0,
        })
    }

    /// Test-only hook: pool-growth events (new plans/results or plan
    /// vector reallocation) observed after epoch 0. The allocation-free
    /// steady-state contract says this stays 0 for the whole run.
    #[doc(hidden)]
    pub fn steady_plan_reallocs(&self) -> u64 {
        self.plan_reallocs
    }

    pub fn engine(&self) -> &'a Engine {
        self.eng
    }

    /// Total segments across the dataset (observability).
    pub fn total_segments(&self) -> usize {
        self.task.total_segments()
    }

    /// Global optimization-step counter.
    pub fn steps_done(&self) -> u32 {
        self.step
    }

    /// Split `self` into the task and a [`CoreEnv`] over the remaining
    /// state (disjoint field borrows).
    fn split_env(&mut self) -> (&mut T, CoreEnv<'_>) {
        let GstCore {
            task,
            eng,
            cfg,
            ps,
            table,
            rng,
            obs,
            step,
            accum,
            ..
        } = self;
        (
            task,
            CoreEnv {
                eng: *eng,
                cfg: &*cfg,
                ps,
                table,
                rng,
                obs: &*obs,
                step,
                accum,
            },
        )
    }

    /// Run the full schedule: `epochs` of training, then (for +F methods)
    /// the finetuning phase, recording the metric curve.
    pub fn train(&mut self) -> Result<RunResult> {
        let mut curve = Curve::default();
        let eval_train = self.task.eval_train_subset();
        for epoch in 0..self.cfg.epochs {
            if self.cfg.method == Method::FullGraph {
                let (task, mut env) = self.split_env();
                task.full_graph_epoch(&mut env)?;
            } else {
                self.gst_epoch(epoch)?;
            }
            if epoch == 0 {
                self.first_epoch_steps = self.obs.step_count();
                // pools are warm: any further plan growth is a bug
                self.steady = true;
            }
            self.record_epoch_telemetry(epoch + 1);
            if (epoch + 1) % self.cfg.eval_every == 0
                || epoch + 1 == self.cfg.epochs
            {
                let _eval = self.obs.span(Phase::Eval);
                let tr =
                    self.task.eval_metric(self.eng, &self.ps, &eval_train)?;
                let te = self.task.eval_metric(
                    self.eng,
                    &self.ps,
                    self.task.test_items(),
                )?;
                curve.push(epoch + 1, tr, te);
            }
        }
        if self.cfg.method.finetunes() {
            // finetune steps are not part of the Table 3 per-iteration
            // time (the paper reports the main-loop fwd+bwd time)
            self.obs.pause_steps();
            {
                let (task, mut env) = self.split_env();
                let _ft = env.obs.span(Phase::Finetune);
                task.finetune(&mut env, &mut curve, &eval_train)?;
            }
            self.obs.resume_steps();
        }
        let (train_metric, test_metric) = {
            let _eval = self.obs.span(Phase::Eval);
            let tr =
                self.task.eval_metric(self.eng, &self.ps, &eval_train)?;
            let te = self.task.eval_metric(
                self.eng,
                &self.ps,
                self.task.test_items(),
            )?;
            (tr, te)
        };
        let call_counts = self.eng.call_counts();
        let fill_cache = self.task.fill_cache_stats();
        let param_cache = self.eng.param_cache_stats();
        if self.obs.is_enabled() {
            self.obs.gauge("table_bytes", self.table.bytes() as f64);
            self.obs.gauge("table_coverage", self.table.coverage());
            self.obs.gauge(
                "fill_cache_bytes",
                self.task.fill_cache_bytes() as f64,
            );
        }
        let report = self.build_report(
            train_metric,
            test_metric,
            &curve,
            &call_counts,
            fill_cache,
            param_cache,
        );
        self.obs.flush();
        Ok(RunResult {
            train_metric,
            test_metric,
            // steady-state: exclude epoch 0's cold-table steps
            step_ms: self.obs.step_mean_ms_from(self.first_epoch_steps),
            step_p50_ms: self.obs.step_p50_ms(),
            step_p95_ms: self.obs.step_p95_ms(),
            step_max_ms: self.obs.step_max_ms(),
            curve,
            call_counts,
            fill_cache,
            param_cache,
            report,
        })
    }

    /// Sample table coverage + the staleness distribution into the epoch
    /// telemetry (no-op when the recorder is disabled).
    fn record_epoch_telemetry(&self, epoch: usize) {
        if !self.obs.is_enabled() {
            return;
        }
        let mut hist = Histogram::staleness();
        self.table
            .for_each_staleness(self.step, |age| hist.observe(age as f64));
        self.obs.record_epoch(EpochStats {
            epoch,
            coverage: self.table.coverage(),
            mean_staleness: self.table.mean_staleness(self.step),
            hist,
        });
    }

    /// Contention section of the run report: per-lock wait/acquisition
    /// counters from the engine's and the task's timed locks, their
    /// total, the same waits re-aggregated by the *phase* that paid
    /// them (`by_phase`, v3), and the serial table write-back cost (the
    /// one serial region the commit phase can't parallelize away).
    fn contention_json(&self) -> Json {
        let mut entries = self.eng.lock_stats();
        for (name, s) in self.task.contention() {
            entries.push((format!("task.{name}"), s));
        }
        let total_ms: f64 =
            entries.iter().map(|(_, s)| s.wait_ms()).sum();
        // flame attribution: sum each lock's per-slot wait split, then
        // name the slots through the phase taxonomy
        let mut by_slot = [0u64; crate::util::sync::PHASE_SLOTS];
        for (_, s) in &entries {
            for (acc, &ns) in by_slot.iter_mut().zip(&s.wait_ns_by) {
                *acc += ns;
            }
        }
        let by_phase = Json::Obj(
            by_slot
                .iter()
                .enumerate()
                .map(|(slot, &ns)| {
                    (
                        Phase::slot_name(slot).to_string(),
                        Json::num(ns as f64 / 1e6),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            (
                "locks",
                Json::Obj(
                    entries
                        .into_iter()
                        .map(|(k, s)| (k, s.to_json()))
                        .collect(),
                ),
            ),
            ("total_wait_ms", Json::num(total_ms)),
            ("by_phase", by_phase),
            (
                "table_writeback_ms",
                Json::num(self.table_writeback_ns as f64 / 1e6),
            ),
        ])
    }

    /// Assemble the `gst-run-report/v3` document: run context plus every
    /// recorder view plus engine-side accounting (v2 added the `workers`
    /// and `contention` sections, v3 adds `contention.by_phase`; every
    /// earlier field is unchanged, so readers accept v1–v3). Built for
    /// every run — with the recorder disabled the telemetry sections
    /// are just empty.
    fn build_report(
        &self,
        train_metric: f64,
        test_metric: f64,
        curve: &Curve,
        call_counts: &HashMap<String, usize>,
        fill_cache: CacheStats,
        param_cache: CacheStats,
    ) -> Json {
        let m = &self.eng.manifest;
        let cfg = &self.cfg;
        let calls = Json::Obj(
            call_counts
                .iter()
                .map(|(k, &v)| (k.clone(), Json::num(v as f64)))
                .collect(),
        );
        let per_call_ms = Json::Obj(
            self.eng
                .call_ms()
                .into_iter()
                .map(|(k, v)| (k, Json::num(v)))
                .collect(),
        );
        Json::obj(vec![
            ("schema", Json::str("gst-run-report/v3")),
            ("method", Json::str(cfg.method.name())),
            ("dataset", Json::str(&m.dataset)),
            ("backbone", Json::str(&m.backbone)),
            (
                "config",
                Json::obj(vec![
                    ("epochs", Json::num(cfg.epochs as f64)),
                    (
                        "finetune_epochs",
                        Json::num(cfg.finetune_epochs as f64),
                    ),
                    ("keep_p", Json::num(cfg.keep_p as f64)),
                    ("workers", Json::num(cfg.workers as f64)),
                    (
                        "micro_batches",
                        Json::num(cfg.micro_batches as f64),
                    ),
                    ("seed", Json::num(cfg.seed as f64)),
                    (
                        "fill_cache_mb",
                        Json::num(cfg.fill_cache_mb as f64),
                    ),
                ]),
            ),
            (
                "metrics",
                Json::obj(vec![
                    ("train", Json::num(train_metric)),
                    ("test", Json::num(test_metric)),
                ]),
            ),
            ("curve", curve.to_json()),
            ("steps", self.obs.steps_json(self.first_epoch_steps)),
            ("phases", self.obs.phases_json()),
            ("workers", self.obs.workers_json()),
            ("contention", self.contention_json()),
            ("staleness", self.obs.staleness_json()),
            ("sed", self.obs.sed_json()),
            (
                "caches",
                Json::obj(vec![
                    ("fill", fill_cache.to_json()),
                    ("param_literal", param_cache.to_json()),
                ]),
            ),
            ("calls", calls),
            (
                "engine",
                Json::obj(vec![
                    ("per_call_ms", per_call_ms),
                    (
                        "marshalled_bytes",
                        Json::num(self.eng.marshalled_bytes() as f64),
                    ),
                ]),
            ),
            ("gauges", self.obs.gauges_json()),
            ("counters", self.obs.counters_json()),
        ])
    }

    // -- the shared GST inner loop (Alg. 1/2) -------------------------------

    fn gst_epoch(&mut self, epoch: usize) -> Result<()> {
        let mut order = self.task.train_items().to_vec();
        self.rng
            .stream_indexed("epoch", epoch as u64)
            .shuffle(&mut order);
        let units = self.task.plan_epoch(&order);
        let group = self.cfg.micro_batches.max(1);
        for chunk in units.chunks(group) {
            self.run_group(chunk)?;
        }
        Ok(())
    }

    /// One optimization step: plan → parallel compute → ordered commit.
    fn run_group(&mut self, units: &[Vec<usize>]) -> Result<()> {
        let eng = self.eng;
        let m = &eng.manifest;
        let (b, td) = (m.batch, m.table_dim);
        let method = self.cfg.method;
        let mode = method.sed(self.cfg.keep_p);
        self.obs.set_step(self.step as u64);
        self.obs.step_start();
        let _step_span = self.obs.span(Phase::Step);

        // 1. plan (sequential; table reads see the group-start snapshot)
        // — the plan pool is reset-and-reused: after epoch 0 this phase
        // touches no allocator (every growth event is counted)
        let nplans = units.len();
        let mut sed_total = 0u64;
        let mut sed_dropped = 0u64;
        {
            let _sample = self.obs.span(Phase::Sample);
            for (k, unit) in units.iter().enumerate() {
                if self.plans.len() <= k {
                    if self.steady {
                        self.plan_reallocs += 1;
                    }
                    self.plans.push(StepPlan::with_capacity(
                        b,
                        td,
                        self.fresh_cap,
                    ));
                }
                let step_id = self.step + k as u32;
                let mut rng = self.rng.stream_indexed("step", step_id as u64);
                let plan = &mut self.plans[k];
                let caps0 =
                    (plan.slots.capacity(), plan.fresh.capacity());
                plan.slots.clear();
                let ctx =
                    self.task.begin_step(unit, &mut rng, &mut plan.slots);
                assert_eq!(
                    plan.slots.len(),
                    b,
                    "task must describe all B slots"
                );
                plan.ctx = Some(ctx);
                plan.stale.fill(0.0);
                plan.fresh.clear();
                plan.step_id = step_id;
                for slot in 0..b {
                    let j = plan.slots[slot].num_segments;
                    let s = rng.below(j);
                    plan.sampled[slot] = s;
                    plan.eta_fresh[slot] = sed_weights_into(
                        mode,
                        j,
                        s,
                        &mut rng,
                        &mut self.sed_buf,
                    );
                    let row = plan.slots[slot].row;
                    for (seg, &eta) in self.sed_buf.iter().enumerate() {
                        if seg == s {
                            continue;
                        }
                        sed_total += 1;
                        if eta == 0.0 {
                            // SED dropped this stale segment (Eq. 1)
                            sed_dropped += 1;
                            continue;
                        }
                        if !method.fresh_stale() {
                            if let Some(h) = self.table.get(row, seg) {
                                for d in 0..td {
                                    plan.stale[slot * td + d] +=
                                        eta * h[d];
                                }
                                continue;
                            }
                            // else: cold entry (first epoch) — recompute
                            // fresh AND write back, Alg. 2's first touch
                        }
                        plan.fresh.push((slot, seg, eta));
                    }
                }
                if self.steady
                    && (plan.slots.capacity(), plan.fresh.capacity())
                        != caps0
                {
                    self.plan_reallocs += 1;
                }
            }
        }
        self.obs.add("sed_stale_total", sed_total);
        self.obs.add("sed_stale_dropped", sed_dropped);

        // 2. compute (parallel): contiguous shards keep plan order.
        // Results are core-owned like the plans — each worker gets its
        // shard of the result pool alongside its reusable buffers.
        while self.results.len() < nplans {
            if self.steady {
                self.plan_reallocs += 1;
            }
            self.results
                .push(StepResult::with_capacity(m, self.fresh_cap));
        }
        let nworkers = self.bufs.len().min(nplans).max(1);
        let ranges = threads::chunk_ranges(nplans, nworkers);
        let task = &self.task;
        let ps = &self.ps;
        let obs = &self.obs;
        let plans_ref = &self.plans[..nplans];
        let ranges_ref = &ranges;
        let mut states: Vec<(&mut BatchBufs, &mut [StepResult])> =
            Vec::with_capacity(nworkers);
        {
            let mut bufs_rest = &mut self.bufs[..nworkers];
            let mut res_rest = &mut self.results[..nplans];
            for r in &ranges {
                let (b1, b2) = bufs_rest.split_at_mut(1);
                let (r1, r2) = res_rest.split_at_mut(r.len());
                states.push((&mut b1[0], r1));
                bufs_rest = b2;
                res_rest = r2;
            }
        }
        let worker_out = threads::fork_join_with(&mut states, |w, st| {
            // tag this worker's spans and time its busy interval —
            // the raw material for the imbalance gauge
            let _scope = obs.worker_scope(w);
            let t0 = Instant::now();
            let (wb, wres) = st;
            let mut out = Ok(());
            for (pi, res) in ranges_ref[w].clone().zip(wres.iter_mut()) {
                if let Err(e) = compute_step(
                    eng,
                    task,
                    ps,
                    &plans_ref[pi],
                    &mut **wb,
                    res,
                    obs,
                ) {
                    out = Err(e);
                    break;
                }
            }
            (out, t0.elapsed().as_nanos() as u64)
        });
        drop(states);
        // record every worker's busy time before error propagation, so a
        // failing step still leaves consistent telemetry behind
        let busy: Vec<u64> =
            worker_out.iter().map(|(_, ns)| *ns).collect();
        self.obs.record_fork_join(&busy);
        for (r, _) in worker_out {
            r?;
        }

        // 3. commit (sequential, micro-batch order — deterministic for
        // any worker count). The commit span also covers gradient
        // reduction and the optimizer apply: everything serial after
        // the workers join.
        {
            let _commit = self.obs.span(Phase::TableCommit);
            let t0 = Instant::now();
            let uses_table = method.uses_table();
            let batched = self.cfg.batched_writeback;
            for (plan, res) in
                self.plans[..nplans].iter().zip(&self.results[..nplans])
            {
                if batched {
                    commit_step_batched(
                        &mut self.table,
                        uses_table,
                        plan,
                        res,
                        td,
                        &mut self.commit,
                    );
                } else {
                    commit_step(
                        &mut self.table,
                        uses_table,
                        plan,
                        res,
                        td,
                    );
                }
            }
            self.table_writeback_ns +=
                t0.elapsed().as_nanos() as u64;
            for res in &self.results[..nplans] {
                self.accum.add(&res.out.grads);
            }
            let lr = effective_lr(&self.cfg, eng);
            let avg = self.accum.mean();
            ops::apply(eng, &mut self.ps, avg, lr)?;
        }
        // refresh the cumulative lock-wait total for the heartbeat line
        // and the report (engine caches + any task-owned locks)
        if self.obs.is_enabled() {
            let task_wait: u64 = self
                .task
                .contention()
                .iter()
                .map(|(_, s)| s.wait_ns)
                .sum();
            self.obs.set_lock_wait_ns(eng.lock_wait_ns() + task_wait);
        }
        self.step += nplans as u32;
        self.obs.step_stop();
        Ok(())
    }
}

/// Execute one planned micro-batch on a worker's buffers: recompute the
/// planned fresh stale segments through batched `embed_fwd` (staged in
/// the same (nodes, adj, mask) tensors the grad batch overwrites after),
/// then assemble the grad batch and run `grad_step`. Read-only on
/// everything shared.
fn compute_step<T: GstTask>(
    eng: &Engine,
    task: &T,
    ps: &ParamStore,
    plan: &StepPlan<T::StepCtx>,
    bufs: &mut BatchBufs,
    res: &mut StepResult,
    obs: &Recorder,
) -> Result<()> {
    let m = &eng.manifest;
    let (b, td) = (m.batch, m.table_dim);
    let ctx = plan.ctx();
    // stale aggregate starts from the table-served part of the plan
    bufs.stale.copy_from_slice(&plan.stale);
    // fresh stale embeddings, batched through embed_fwd into the
    // result's flat arena (preallocated for the worst case)
    res.fresh_embs.clear();
    for chunk in plan.fresh.chunks(b) {
        {
            let _fill = obs.span(Phase::Fill);
            for bslot in 0..b {
                let (slot, seg, _) =
                    chunk[padded_index(bslot, chunk.len())];
                let (nodes, adj, mask) = bufs.slot(m, bslot);
                task.fill_slot(ctx, slot, seg, nodes, adj, mask);
            }
        }
        let h = {
            let _fwd = obs.span(Phase::EmbedFwd);
            ops::embed_fwd(eng, ps, &bufs.nodes, &bufs.adj, &bufs.mask)?
        };
        for (i, &(slot, _seg, eta)) in chunk.iter().enumerate() {
            let hv = &h[i * td..(i + 1) * td];
            for d in 0..td {
                bufs.stale[slot * td + d] += eta * hv[d];
            }
            res.fresh_embs.extend_from_slice(hv);
        }
    }
    // grad batch: sampled segments + SED weights + loss buffers
    {
        let _fill = obs.span(Phase::Fill);
        for slot in 0..b {
            bufs.eta[slot] = plan.eta_fresh[slot];
            bufs.invj[slot] = plan.slots[slot].invj;
            let (nodes, adj, mask) = bufs.slot(m, slot);
            task.fill_slot(
                ctx,
                slot,
                plan.sampled[slot],
                nodes,
                adj,
                mask,
            );
        }
        // reused buffers: tasks only set the pair mask's 1-entries
        bufs.pair.fill(0.0);
        task.fill_loss(ctx, bufs);
    }
    {
        let _grad = obs.span(Phase::Grad);
        ops::grad_step_into(eng, ps, bufs, &mut res.out)?;
    }
    Ok(())
}

/// Table write-back for one micro-batch (Alg. 2 line 7): fresh stale
/// recomputations first, then the sampled segments' embeddings, all
/// versioned with the micro-batch's global step index. The row-by-row
/// reference committer (`cfg.batched_writeback = false`);
/// [`commit_step_batched`] must produce the identical table.
fn commit_step<C>(
    table: &mut EmbeddingTable,
    uses_table: bool,
    plan: &StepPlan<C>,
    res: &StepResult,
    td: usize,
) {
    if !uses_table {
        return;
    }
    for (k, &(slot, seg, _eta)) in plan.fresh.iter().enumerate() {
        let h = &res.fresh_embs[k * td..(k + 1) * td];
        table.put(plan.slots[slot].row, seg, h, plan.step_id);
    }
    for (slot, spec) in plan.slots.iter().enumerate() {
        let h = &res.out.h_s[slot * td..(slot + 1) * td];
        table.put(spec.row, plan.sampled[slot], h, plan.step_id);
    }
}

/// [`commit_step`] through the batched committer: collect every
/// write-back's arena slot (fresh entries first, then sampled — the
/// sequential order), then flush as sorted contiguous runs. Last write
/// wins per slot exactly as in the sequential loop (the TPU task emits
/// duplicate rows within one micro-batch when configs repeat, and a
/// sampled write must beat a fresh one for the same slot).
fn commit_step_batched<C>(
    table: &mut EmbeddingTable,
    uses_table: bool,
    plan: &StepPlan<C>,
    res: &StepResult,
    td: usize,
    batch: &mut CommitBatch,
) {
    if !uses_table {
        return;
    }
    batch.begin();
    for &(slot, seg, _eta) in &plan.fresh {
        batch.push(table.slot_index(plan.slots[slot].row, seg));
    }
    for (slot, spec) in plan.slots.iter().enumerate() {
        batch.push(table.slot_index(spec.row, plan.sampled[slot]));
    }
    let nfresh = plan.fresh.len() as u32;
    batch.flush(table, plan.step_id, |id| {
        if id < nfresh {
            let k = id as usize;
            &res.fresh_embs[k * td..(k + 1) * td]
        } else {
            let s = (id - nfresh) as usize;
            &res.out.h_s[s * td..(s + 1) * td]
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padded_index_repeats_last_entry() {
        // full chunk: identity
        assert_eq!(
            (0..4).map(|s| padded_index(s, 4)).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
        // short final chunk of 2 in a 4-slot batch: trailing slots
        // repeat the LAST entry (index 1), not entry 0
        assert_eq!(
            (0..4).map(|s| padded_index(s, 2)).collect::<Vec<_>>(),
            vec![0, 1, 1, 1]
        );
        assert_eq!(padded_index(7, 1), 0);
    }

    #[test]
    fn sed_weights_honor_every_mode() {
        let mut rng = Pcg64::new(3, 9);
        let (j, s) = (6usize, 2usize);
        let keep = sed_weights(SedMode::KeepAll, j, s, &mut rng);
        assert_eq!(keep.eta_fresh, 1.0);
        assert_eq!(keep.eta_stale[s], 0.0);
        assert!(keep
            .eta_stale
            .iter()
            .enumerate()
            .all(|(i, &e)| i == s || e == 1.0));
        let drop = sed_weights(SedMode::DropAll, j, s, &mut rng);
        assert_eq!(drop.eta_fresh, j as f32);
        assert!(drop.eta_stale.iter().all(|&e| e == 0.0));
        for p in [0.0f32, 0.3, 1.0] {
            let w = sed_weights(SedMode::Draw(p), j, s, &mut rng);
            assert!((w.eta_fresh - (p + (1.0 - p) * j as f32)).abs() < 1e-6);
            assert_eq!(w.eta_stale[s], 0.0);
            assert!(w.eta_stale.iter().all(|&e| e == 0.0 || e == 1.0));
        }
    }

    fn plan_and_result() -> (StepPlan<()>, StepResult) {
        let slots = vec![
            SlotSpec { row: 0, num_segments: 3, invj: 1.0 / 3.0 },
            SlotSpec { row: 1, num_segments: 2, invj: 0.5 },
        ];
        let plan = StepPlan {
            ctx: Some(()),
            slots,
            sampled: vec![2, 0],
            eta_fresh: vec![1.0, 1.0],
            stale: vec![0.0; 2 * 2],
            fresh: vec![(0, 1, 1.0)],
            step_id: 7,
        };
        let res = StepResult {
            out: ops::StepOut {
                loss: 0.0,
                grads: vec![],
                h_s: vec![1.0, 2.0, 3.0, 4.0],
            },
            fresh_embs: vec![9.0, 9.5],
        };
        (plan, res)
    }

    #[test]
    fn commit_advances_versions_and_values() {
        let mut table = EmbeddingTable::new(&[3, 2], 2);
        let (plan, res) = plan_and_result();
        commit_step(&mut table, true, &plan, &res, 2);
        // fresh stale write-back for slot 0, seg 1
        assert_eq!(table.get(0, 1).unwrap(), &[9.0, 9.5]);
        // sampled-segment write-backs
        assert_eq!(table.get(0, 2).unwrap(), &[1.0, 2.0]);
        assert_eq!(table.get(1, 0).unwrap(), &[3.0, 4.0]);
        // versions advance to the micro-batch's step id
        assert_eq!(table.staleness(0, 2, 7), Some(0));
        assert_eq!(table.staleness(0, 1, 9), Some(2));
        // untouched entries stay unwritten
        assert!(table.get(0, 0).is_none());
        assert!(table.get(1, 1).is_none());
    }

    #[test]
    fn commit_is_a_noop_without_table() {
        let mut table = EmbeddingTable::new(&[3, 2], 2);
        let (plan, res) = plan_and_result();
        commit_step(&mut table, false, &plan, &res, 2);
        assert_eq!(table.coverage(), 0.0);
    }

    #[test]
    fn later_commit_wins_conflicts_deterministically() {
        let mut table = EmbeddingTable::new(&[3, 2], 2);
        let (plan, res) = plan_and_result();
        commit_step(&mut table, true, &plan, &res, 2);
        let (mut plan2, mut res2) = plan_and_result();
        plan2.step_id = 8;
        res2.out.h_s = vec![5.0, 6.0, 7.0, 8.0];
        commit_step(&mut table, true, &plan2, &res2, 2);
        assert_eq!(table.get(0, 2).unwrap(), &[5.0, 6.0]);
        assert_eq!(table.staleness(0, 2, 8), Some(0));
    }

    /// A plan with every conflict shape the batched committer must
    /// preserve: a fresh entry and a sampled entry targeting the same
    /// slot (sampled wins: it is pushed later), and two batch slots
    /// sampling the same (row, segment) (the later slot wins — the TPU
    /// task's duplicate-config case).
    fn conflicting_plan_and_result() -> (StepPlan<()>, StepResult) {
        let slots = vec![
            SlotSpec { row: 0, num_segments: 3, invj: 1.0 / 3.0 },
            SlotSpec { row: 1, num_segments: 2, invj: 0.5 },
            SlotSpec { row: 1, num_segments: 2, invj: 0.5 },
        ];
        let plan = StepPlan {
            ctx: Some(()),
            slots,
            // slots 1 and 2 both sample row 1 seg 0
            sampled: vec![1, 0, 0],
            eta_fresh: vec![1.0; 3],
            stale: vec![0.0; 3 * 2],
            // fresh also writes (row 0, seg 1) — the slot sampled writes
            fresh: vec![(0, 1, 1.0), (0, 2, 1.0)],
            step_id: 7,
        };
        let res = StepResult {
            out: ops::StepOut {
                loss: 0.0,
                grads: vec![],
                h_s: vec![10.0, 11.0, 20.0, 21.0, 30.0, 31.0],
            },
            fresh_embs: vec![1.0, 1.5, 2.0, 2.5],
        };
        (plan, res)
    }

    #[test]
    fn batched_commit_matches_sequential() {
        for (plan, res) in
            [plan_and_result(), conflicting_plan_and_result()]
        {
            let rows = &[3usize, 2];
            let mut seq = EmbeddingTable::new(rows, 2);
            let mut bat = EmbeddingTable::new(rows, 2);
            let mut scratch = CommitBatch::new();
            commit_step(&mut seq, true, &plan, &res, 2);
            commit_step_batched(
                &mut bat, true, &plan, &res, 2, &mut scratch,
            );
            for (g, segs) in rows.iter().enumerate() {
                for s in 0..*segs {
                    assert_eq!(seq.get(g, s), bat.get(g, s), "({g},{s})");
                    assert_eq!(
                        seq.staleness(g, s, 9),
                        bat.staleness(g, s, 9)
                    );
                }
            }
        }
    }

    #[test]
    fn batched_commit_keeps_last_write_per_slot() {
        let (plan, res) = conflicting_plan_and_result();
        let mut table = EmbeddingTable::new(&[3, 2], 2);
        let mut scratch = CommitBatch::new();
        commit_step_batched(
            &mut table, true, &plan, &res, 2, &mut scratch,
        );
        // sampled write (slot 0 → row 0 seg 1: h_s[0..2]) beats the
        // fresh recomputation of the same (row, seg)
        assert_eq!(table.get(0, 1).unwrap(), &[10.0, 11.0]);
        // the LAST duplicate sampled slot (slot 2) wins row 1 seg 0
        assert_eq!(table.get(1, 0).unwrap(), &[30.0, 31.0]);
        // unconflicted fresh entry lands as-is
        assert_eq!(table.get(0, 2).unwrap(), &[2.0, 2.5]);
    }

    #[test]
    fn batched_commit_is_a_noop_without_table() {
        let (plan, res) = plan_and_result();
        let mut table = EmbeddingTable::new(&[3, 2], 2);
        let mut scratch = CommitBatch::new();
        commit_step_batched(
            &mut table, false, &plan, &res, 2, &mut scratch,
        );
        assert_eq!(table.coverage(), 0.0);
    }

    #[test]
    fn commit_batch_scratch_reuses_capacity() {
        let (plan, res) = conflicting_plan_and_result();
        let mut table = EmbeddingTable::new(&[3, 2], 2);
        let mut scratch = CommitBatch::with_capacity(8, 2);
        let caps0 =
            (scratch.entries.capacity(), scratch.staged.capacity());
        for _ in 0..10 {
            commit_step_batched(
                &mut table, true, &plan, &res, 2, &mut scratch,
            );
        }
        assert_eq!(
            (scratch.entries.capacity(), scratch.staged.capacity()),
            caps0
        );
    }
}
