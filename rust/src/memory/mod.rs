//! Analytic activation-memory model — the paper's OOM boundary, computed.
//!
//! The paper's Tables 1–3 report "OOM" for Full Graph Training on
//! MalNet-Large and TpuGraphs on a 16 GB V100. Memory for GNN training is
//! dominated by stored activations, which scale with (nodes + edges) ×
//! hidden × layers (Zhang et al. '22). We model that at *paper scale* —
//! hidden 300, V100 16 GB — so the OOM rows are decided by the same
//! physics, while the actual compute runs at our scaled-down shapes.
//!
//! GST's claim, visible directly in [`MemoryModel::gst_peak_bytes`]: peak memory depends
//! only on (max segment size × batch), never on the full graph size.

/// Model/hardware description for the memory estimate.
#[derive(Clone, Copy, Debug)]
pub struct MemoryModel {
    /// activation hidden width (paper: 300 for GCN/SAGE, 64 for GPS)
    pub hidden: usize,
    /// number of activation-storing layers (pre + mp + post)
    pub layers: usize,
    /// bytes per element (f32 = 4)
    pub elem_bytes: usize,
    /// activation copies per layer (fwd value + grad workspace)
    pub copies: usize,
    /// device memory budget in bytes (V100: 16 GB)
    pub budget: usize,
    /// fraction of the budget usable for activations (rest: params, opt
    /// state, workspace, framework overhead)
    pub activation_fraction: f64,
}

pub const V100_16GB: usize = 16 * 1024 * 1024 * 1024;

impl MemoryModel {
    /// The paper's MalNet configuration (Table 5): hidden 300, 1+2+1 layers.
    pub fn malnet_paper(backbone: &str) -> MemoryModel {
        let (hidden, layers) = match backbone {
            "gps" => (64, 5), // GatedGCN+Performer, 3 mp + pre/post
            _ => (300, 4),
        };
        MemoryModel {
            hidden,
            layers,
            elem_bytes: 4,
            copies: 2,
            budget: V100_16GB,
            activation_fraction: 0.85,
        }
    }

    /// Model for a dataset name as spelled in `TrainConfig`/manifests
    /// (`"tpu"` vs the MalNet splits) — the telemetry entry point.
    pub fn for_dataset(dataset: &str, backbone: &str) -> MemoryModel {
        if dataset == "tpu" {
            MemoryModel::tpu_paper()
        } else {
            MemoryModel::malnet_paper(backbone)
        }
    }

    /// TpuGraphs configuration: hidden 128, 4 mp + 3 post layers.
    pub fn tpu_paper() -> MemoryModel {
        MemoryModel {
            hidden: 128,
            layers: 7,
            elem_bytes: 4,
            copies: 2,
            budget: V100_16GB,
            activation_fraction: 0.85,
        }
    }

    /// Peak activation bytes for backprop over a set of live node/edge
    /// counts (one entry per graph in the batch).
    pub fn activation_bytes(&self, nodes: usize, edges: usize) -> usize {
        // node activations per layer + edge messages per mp layer
        let per_layer = nodes * self.hidden + edges * self.hidden / 2;
        per_layer * self.layers * self.copies * self.elem_bytes
    }

    /// Full Graph Training: all nodes/edges of every graph in the batch are
    /// live simultaneously.
    pub fn full_graph_peak(&self, batch: &[(usize, usize)]) -> usize {
        batch
            .iter()
            .map(|&(n, e)| self.activation_bytes(n, e))
            .sum()
    }

    /// GST: only the sampled segments are live; everything else is
    /// inference (GST) or a table read (GST+E) with O(1) extra memory.
    /// `max_seg_nodes`/`max_seg_edges` bound any segment by construction.
    pub fn gst_peak_bytes(
        &self,
        batch_graphs: usize,
        sampled_per_graph: usize,
        max_seg_nodes: usize,
        max_seg_edges: usize,
    ) -> usize {
        batch_graphs
            * sampled_per_graph
            * self.activation_bytes(max_seg_nodes, max_seg_edges)
    }

    pub fn fits(&self, peak: usize) -> bool {
        (peak as f64) <= self.budget as f64 * self.activation_fraction
    }

    /// Would Full Graph Training OOM on this batch? (The Tables 1–3 rows.)
    pub fn full_graph_ooms(&self, batch: &[(usize, usize)]) -> bool {
        !self.fits(self.full_graph_peak(batch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper-scale sanity: MalNet-Tiny fits, MalNet-Large OOMs (Table 1).
    #[test]
    fn paper_scale_oom_boundary() {
        let m = MemoryModel::malnet_paper("sage");
        // Tiny: batch of 16 graphs, ~1.4k nodes / 2.9k edges each
        let tiny: Vec<(usize, usize)> = vec![(1_410, 2_860); 16];
        assert!(!m.full_graph_ooms(&tiny), "tiny should fit");
        // Large: batch of 16, avg 47.8k nodes / 225k edges
        let large: Vec<(usize, usize)> = vec![(47_838, 225_474); 16];
        assert!(m.full_graph_ooms(&large), "large should OOM");
        // worst single graph alone (541k nodes, 3.3M edges) also OOMs
        assert!(m.full_graph_ooms(&[(541_571, 3_278_318)]));
    }

    #[test]
    fn tpu_scale_ooms() {
        let m = MemoryModel::tpu_paper();
        let batch: Vec<(usize, usize)> = vec![(38_444, 62_475); 64];
        assert!(m.full_graph_ooms(&batch));
    }

    #[test]
    fn gst_peak_is_constant_in_graph_size() {
        let m = MemoryModel::malnet_paper("sage");
        let p = m.gst_peak_bytes(16, 1, 5_000, 20_000);
        assert!(m.fits(p), "GST must fit: {p}");
        // invariant: doesn't depend on any full-graph quantity — same
        // value whatever dataset it's asked about
        assert_eq!(p, m.gst_peak_bytes(16, 1, 5_000, 20_000));
    }

    #[test]
    fn for_dataset_dispatches_on_name() {
        let t = MemoryModel::for_dataset("tpu", "sage");
        assert_eq!(t.hidden, MemoryModel::tpu_paper().hidden);
        let m = MemoryModel::for_dataset("malnet-tiny", "gps");
        assert_eq!(m.hidden, MemoryModel::malnet_paper("gps").hidden);
    }

    #[test]
    fn activation_bytes_monotone() {
        let m = MemoryModel::malnet_paper("gcn");
        assert!(m.activation_bytes(100, 200) < m.activation_bytes(200, 200));
        assert!(m.activation_bytes(100, 200) < m.activation_bytes(100, 400));
    }

    #[test]
    fn bigger_sampling_needs_more() {
        let m = MemoryModel::malnet_paper("sage");
        let s1 = m.gst_peak_bytes(16, 1, 5_000, 20_000);
        let s2 = m.gst_peak_bytes(16, 2, 5_000, 20_000);
        assert_eq!(s2, 2 * s1);
    }
}
