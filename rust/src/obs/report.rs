//! Report-side serialization for [`Recorder`] — a child module so it can
//! read the recorder's internals without widening its public surface.
//! The full `gst-run-report/v1` document is assembled by the trainer
//! (`train::core`), which owns the run-level context (config, metrics,
//! engine stats) the recorder has no business knowing about.

use std::sync::atomic::Ordering;

use super::{Phase, Recorder};
use crate::util::json::Json;

impl Recorder {
    /// Per-phase `{total_ms, calls}`; every phase key is always present
    /// so report consumers never need existence checks. With parallel
    /// workers the compute-phase totals are summed across threads and
    /// may legitimately exceed wall-clock.
    pub fn phases_json(&self) -> Json {
        Json::Obj(
            Phase::ALL
                .iter()
                .map(|&p| {
                    let i = p.idx();
                    let ns = self.phase_ns[i].load(Ordering::Relaxed);
                    let calls =
                        self.phase_calls[i].load(Ordering::Relaxed);
                    (
                        p.name().to_string(),
                        Json::obj(vec![
                            ("total_ms", Json::num(ns as f64 / 1e6)),
                            ("calls", Json::num(calls as f64)),
                        ]),
                    )
                })
                .collect(),
        )
    }

    /// Per-epoch staleness snapshots, in recording order.
    pub fn staleness_json(&self) -> Json {
        Json::arr(self.epochs.lock().unwrap().iter().map(|e| {
            Json::obj(vec![
                ("epoch", Json::num(e.epoch as f64)),
                ("coverage", Json::num(e.coverage)),
                ("mean", Json::num(e.mean_staleness)),
                ("hist", e.hist.to_json()),
            ])
        }))
    }

    /// SED drop accounting from the plan-loop counters (Eq. 1: a stale
    /// slot is "dropped" when its Bernoulli η is 0).
    pub fn sed_json(&self) -> Json {
        let total = self.counter("sed_stale_total");
        let dropped = self.counter("sed_stale_dropped");
        let rate = if total == 0 {
            0.0
        } else {
            dropped as f64 / total as f64
        };
        Json::obj(vec![
            ("stale_total", Json::num(total as f64)),
            ("stale_dropped", Json::num(dropped as f64)),
            ("drop_rate", Json::num(rate)),
        ])
    }

    pub fn counters_json(&self) -> Json {
        Json::Obj(
            self.counters
                .lock()
                .unwrap()
                .iter()
                .map(|(k, &v)| (k.clone(), Json::num(v as f64)))
                .collect(),
        )
    }

    pub fn gauges_json(&self) -> Json {
        Json::Obj(
            self.gauges
                .lock()
                .unwrap()
                .iter()
                .map(|(k, &v)| (k.clone(), Json::num(v)))
                .collect(),
        )
    }

    /// Worker-attribution section (`gst-run-report/v2`): cumulative
    /// per-worker compute busy time, fork-join count and the imbalance
    /// gauge. An empty run (or a disabled recorder) reports zero workers.
    pub fn workers_json(&self) -> Json {
        let busy = self.worker_busy_ms();
        Json::obj(vec![
            ("count", Json::num(busy.len() as f64)),
            (
                "fork_joins",
                Json::num(self.fork_joins.load(Ordering::Relaxed) as f64),
            ),
            (
                "busy_ms",
                Json::arr(busy.iter().map(|&ms| Json::num(ms))),
            ),
            (
                "imbalance_pct",
                Json::num(super::imbalance_pct(&busy)),
            ),
        ])
    }

    /// Step wall-clock stats; the first `warmup` samples are excluded
    /// from the steady-state mean (Table 3 skips the cold first epoch).
    pub fn steps_json(&self, warmup: usize) -> Json {
        let t = self.steps.lock().unwrap();
        Json::obj(vec![
            ("count", Json::num(t.count() as f64)),
            ("warmup_steps", Json::num(warmup as f64)),
            ("mean_ms", Json::num(t.mean_ms())),
            ("steady_mean_ms", Json::num(t.mean_ms_from(warmup))),
            ("p50_ms", Json::num(t.p50_ms())),
            ("p95_ms", Json::num(t.p95_ms())),
            ("max_ms", Json::num(t.max_ms())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::super::{
        EpochStats, Histogram, ObsConfig, Phase, Recorder,
    };

    fn recording() -> Recorder {
        Recorder::new(&ObsConfig {
            record: true,
            ..ObsConfig::default()
        })
        .unwrap()
    }

    #[test]
    fn phases_json_lists_every_phase() {
        let r = recording();
        drop(r.span(Phase::Sample));
        let j = r.phases_json();
        assert_eq!(j.as_obj().unwrap().len(), Phase::ALL.len());
        assert_eq!(j.at("sample").at("calls").as_f64(), Some(1.0));
        assert_eq!(j.at("finetune").at("calls").as_f64(), Some(0.0));
    }

    #[test]
    fn sed_json_rates() {
        let r = recording();
        r.add("sed_stale_total", 10);
        r.add("sed_stale_dropped", 4);
        let j = r.sed_json();
        assert_eq!(j.at("stale_total").as_f64(), Some(10.0));
        assert_eq!(j.at("stale_dropped").as_f64(), Some(4.0));
        let rate = j.at("drop_rate").as_f64().unwrap();
        assert!((rate - 0.4).abs() < 1e-12);
    }

    #[test]
    fn sed_json_defaults_to_zero_without_counters() {
        let j = recording().sed_json();
        assert_eq!(j.at("stale_total").as_f64(), Some(0.0));
        assert_eq!(j.at("drop_rate").as_f64(), Some(0.0));
    }

    #[test]
    fn staleness_json_orders_epochs() {
        let r = recording();
        for epoch in 1usize..=2 {
            let mut h = Histogram::staleness();
            h.observe(epoch as f64);
            r.record_epoch(EpochStats {
                epoch,
                coverage: 0.5,
                mean_staleness: epoch as f64,
                hist: h,
            });
        }
        let j = r.staleness_json();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].at("epoch").as_f64(), Some(1.0));
        assert_eq!(arr[1].at("epoch").as_f64(), Some(2.0));
        assert_eq!(arr[1].at("hist").at("count").as_f64(), Some(1.0));
    }

    #[test]
    fn steps_json_includes_tail_stats() {
        let r = recording();
        for _ in 0..4 {
            r.step_start();
            r.step_stop();
        }
        let j = r.steps_json(1);
        assert_eq!(j.at("count").as_f64(), Some(4.0));
        assert_eq!(j.at("warmup_steps").as_f64(), Some(1.0));
        assert!(j.at("p95_ms").as_f64().unwrap() >= 0.0);
        assert!(
            j.at("max_ms").as_f64().unwrap()
                >= j.at("p50_ms").as_f64().unwrap()
        );
    }
}
