//! PJRT runtime: load the AOT artifacts produced by `make artifacts` and
//! execute them from the training hot path.
//!
//! Wire protocol (fixed by `python/compile/aot.py`):
//! * HLO **text** files, one per function (the xla_extension 0.5.1-safe
//!   interchange — see /opt/xla-example/README.md);
//! * `manifest.json` describing every function's input/output tensors and
//!   the parameter inventory (name-sorted — [`params::ParamStore`] mirrors
//!   that order exactly);
//! * `init_params.bin` raw f32 LE in manifest order.
//!
//! Python never runs at training time; this module is the entire L2/L3
//! boundary.

pub mod engine;
pub mod manifest;
pub mod params;

pub use engine::Engine;
pub use manifest::{FnSpec, Manifest, TensorSpec};
pub use params::ParamStore;
