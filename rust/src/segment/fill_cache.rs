//! Byte-budgeted cache of fully padded `(nodes, adj, mask)` fill blocks.
//!
//! [`super::PreparedSegments::fill`] already reduces a fill to memcpy +
//! sparse scatter; this cache removes even that for the hottest segments
//! by storing the final padded tensors and serving them with three
//! memcpys. Eviction is clock (second chance): a hit sets the entry's
//! reference bit, the clock hand sweeps and evicts the first entry whose
//! bit is clear.
//!
//! The cache is execution-only: a served block is bit-identical to a
//! fresh fill (pinned by the segment property test), so trained
//! parameters never depend on the budget (`cfg.fill_cache_mb`). Hit/miss
//! counters surface through [`CacheStats`].
//!
//! Interior mutability (one [`TimedMutex`]) keeps `get`/`put` callable
//! from the read-only task fill hooks that run concurrently on worker
//! threads; the mutex doubles as the cache's contention probe
//! ([`FillCache::lock_stats`]).

use crate::metrics::CacheStats;
use crate::util::sync::{LockStats, TimedMutex};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock, Weak};

/// Fixed-block-size cache keyed by `(generation, block_key)`: the block
/// key encodes the task's (row, segment) identity, the generation binds
/// entries to one parameter-store lifetime (see [`FillHandle`]), so
/// blocks cached under a stale generation can never be served — they
/// simply miss and age out through the clock sweep.
pub struct FillCache {
    nodes_len: usize,
    adj_len: usize,
    mask_len: usize,
    /// max entries the byte budget holds
    capacity: usize,
    inner: TimedMutex<Inner>,
}

type Key = (u64, u64);

struct Inner {
    map: HashMap<Key, usize>,
    /// key stored in each slot (for eviction-time map removal)
    keys: Vec<Key>,
    /// clock reference bits
    refbit: Vec<bool>,
    hand: usize,
    /// slot-major block storage, grown lazily up to capacity
    data: Vec<f32>,
    hits: u64,
    misses: u64,
}

impl FillCache {
    /// Cache holding at most `budget_mb` MiB of blocks sized for the given
    /// per-tensor lengths. Returns `None` when the budget holds no entry
    /// (`budget_mb = 0` disables caching) or when the block is zero-sized
    /// — an all-zero tensor shape would otherwise make the budget divide
    /// into millions of zero-byte slots.
    pub fn new(
        budget_mb: usize,
        nodes_len: usize,
        adj_len: usize,
        mask_len: usize,
    ) -> Option<FillCache> {
        let block_bytes = (nodes_len + adj_len + mask_len) * 4;
        if block_bytes == 0 {
            return None;
        }
        let capacity = (budget_mb << 20) / block_bytes;
        if capacity == 0 {
            return None;
        }
        Some(FillCache {
            nodes_len,
            adj_len,
            mask_len,
            capacity,
            inner: TimedMutex::new(Inner {
                map: HashMap::new(),
                keys: Vec::new(),
                refbit: Vec::new(),
                hand: 0,
                data: Vec::new(),
                hits: 0,
                misses: 0,
            }),
        })
    }

    fn block(&self) -> usize {
        self.nodes_len + self.adj_len + self.mask_len
    }

    /// Copy `(gen, key)`'s cached block into the output views; returns
    /// `false` (counting a miss) when the key is absent.
    pub fn get(
        &self,
        gen: u64,
        key: u64,
        nodes_out: &mut [f32],
        adj_out: &mut [f32],
        mask_out: &mut [f32],
    ) -> bool {
        let mut inner = self.inner.lock();
        let Some(&slot) = inner.map.get(&(gen, key)) else {
            inner.misses += 1;
            return false;
        };
        inner.hits += 1;
        inner.refbit[slot] = true;
        let base = slot * self.block();
        let (n, a) = (self.nodes_len, self.adj_len);
        nodes_out.copy_from_slice(&inner.data[base..base + n]);
        adj_out.copy_from_slice(&inner.data[base + n..base + n + a]);
        mask_out.copy_from_slice(
            &inner.data[base + n + a..base + self.block()],
        );
        true
    }

    /// Insert (or refresh) `(gen, key)`'s block, clock-evicting when full.
    pub fn put(
        &self,
        gen: u64,
        key: u64,
        nodes: &[f32],
        adj: &[f32],
        mask: &[f32],
    ) {
        assert_eq!(nodes.len(), self.nodes_len);
        assert_eq!(adj.len(), self.adj_len);
        assert_eq!(mask.len(), self.mask_len);
        let key = (gen, key);
        let block = self.block();
        let mut inner = self.inner.lock();
        let slot = if let Some(&s) = inner.map.get(&key) {
            s
        } else if inner.keys.len() < self.capacity {
            let s = inner.keys.len();
            inner.keys.push(key);
            inner.refbit.push(false);
            inner.data.resize((s + 1) * block, 0.0);
            inner.map.insert(key, s);
            s
        } else {
            // clock sweep: clear reference bits until a cold slot appears
            let mut hand = inner.hand;
            while inner.refbit[hand] {
                inner.refbit[hand] = false;
                hand = (hand + 1) % self.capacity;
            }
            inner.hand = (hand + 1) % self.capacity;
            let old = inner.keys[hand];
            inner.map.remove(&old);
            inner.keys[hand] = key;
            inner.map.insert(key, hand);
            hand
        };
        // only hits set the reference bit: a block is "hot" once it has
        // been served, not merely inserted (otherwise a full cache has
        // every bit set and the sweep degenerates to FIFO)
        let base = slot * block;
        let (n, a) = (self.nodes_len, self.adj_len);
        inner.data[base..base + n].copy_from_slice(nodes);
        inner.data[base + n..base + n + a].copy_from_slice(adj);
        inner.data[base + n + a..base + block].copy_from_slice(mask);
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.inner.lock().keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes currently held by resident blocks (telemetry gauge).
    pub fn bytes(&self) -> usize {
        self.len() * self.block() * 4
    }

    /// Cumulative hit/miss counters.
    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock();
        CacheStats { hits: inner.hits, misses: inner.misses }
    }

    /// Contention counters of the cache's internal lock.
    pub fn lock_stats(&self) -> LockStats {
        self.inner.stats()
    }
}

/// Process-wide registry of shared caches, keyed by (budget, block
/// shape): trainers asking for the same configuration get the *same*
/// cache, so an eval sweep prewarms the training fills and both phases
/// report one merged [`CacheStats`]. Entries are weak — a cache dies
/// with its last [`FillHandle`], it is never pinned by the registry.
type RegistryKey = (usize, usize, usize, usize);

fn registry() -> &'static Mutex<HashMap<RegistryKey, Weak<FillCache>>> {
    static REG: OnceLock<Mutex<HashMap<RegistryKey, Weak<FillCache>>>> =
        OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

/// A task's view of the fill-block cache: the (possibly shared) cache
/// plus the generation its entries are keyed under. The handle is the
/// single owner of the byte-accounting and contention surface, so the
/// per-task gauges cannot drift between trainers.
///
/// The generation is the identity half of `ParamStore::cache_key()` —
/// it changes when a trainer binds a *different* store (a new run, a
/// reload), not on every optimizer step. Fill blocks do not depend on
/// parameter values, so keying by the mutation counter would only
/// destroy the cross-phase prewarming this cache exists for; keying by
/// store identity makes entries from a dead trainer self-invalidate (they
/// can never be served again) while eval and training within one run
/// share blocks freely.
pub struct FillHandle {
    cache: Option<Arc<FillCache>>,
    gen: u64,
}

impl FillHandle {
    /// Handle over a cache for the given budget and block shape.
    /// `shared = true` resolves through the process-wide registry (the
    /// default execution mode); `false` builds a private cache (the
    /// pinning tests' control arm). A zero budget or zero-sized block
    /// yields a disabled handle.
    pub fn new(
        budget_mb: usize,
        shared: bool,
        nodes_len: usize,
        adj_len: usize,
        mask_len: usize,
    ) -> FillHandle {
        let cache = if shared {
            let key = (budget_mb, nodes_len, adj_len, mask_len);
            let mut reg = registry().lock().expect("fill cache registry");
            match reg.get(&key).and_then(Weak::upgrade) {
                Some(c) => Some(c),
                None => {
                    reg.retain(|_, w| w.strong_count() > 0);
                    let c = FillCache::new(
                        budget_mb, nodes_len, adj_len, mask_len,
                    )
                    .map(Arc::new);
                    if let Some(c) = &c {
                        reg.insert(key, Arc::downgrade(c));
                    }
                    c
                }
            }
        } else {
            FillCache::new(budget_mb, nodes_len, adj_len, mask_len)
                .map(Arc::new)
        };
        FillHandle { cache, gen: 0 }
    }

    /// A handle that caches nothing (the `budget_mb = 0` shape, useful
    /// as a default).
    pub fn disabled() -> FillHandle {
        FillHandle { cache: None, gen: 0 }
    }

    /// Bind the generation all subsequent lookups/inserts are keyed
    /// under (the parameter-store identity; see the type docs).
    pub fn bind_generation(&mut self, gen: u64) {
        self.gen = gen;
    }

    pub fn is_enabled(&self) -> bool {
        self.cache.is_some()
    }

    /// Serve `key`'s block under the bound generation; `false` = miss
    /// (or cache disabled — a disabled handle counts nothing).
    pub fn get(
        &self,
        key: u64,
        nodes_out: &mut [f32],
        adj_out: &mut [f32],
        mask_out: &mut [f32],
    ) -> bool {
        match &self.cache {
            Some(c) => c.get(self.gen, key, nodes_out, adj_out, mask_out),
            None => false,
        }
    }

    /// Insert `key`'s block under the bound generation (no-op when
    /// disabled).
    pub fn put(&self, key: u64, nodes: &[f32], adj: &[f32], mask: &[f32]) {
        if let Some(c) = &self.cache {
            c.put(self.gen, key, nodes, adj, mask);
        }
    }

    /// Merged hit/miss counters of the underlying cache (all sharers).
    pub fn stats(&self) -> CacheStats {
        self.cache.as_deref().map(FillCache::stats).unwrap_or_default()
    }

    /// Resident bytes of the underlying cache (the one true gauge —
    /// tasks report this instead of re-deriving block arithmetic).
    pub fn bytes(&self) -> usize {
        self.cache.as_deref().map(FillCache::bytes).unwrap_or(0)
    }

    /// Contention rows for the run report, empty when disabled.
    pub fn contention(&self) -> Vec<(String, LockStats)> {
        match &self.cache {
            Some(c) => vec![("fill_cache".into(), c.lock_stats())],
            None => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Blocks of (2 + 4 + 2) floats = 32 bytes; 1 MiB holds thousands.
    fn tiny() -> FillCache {
        FillCache::new(1, 2, 4, 2).unwrap()
    }

    #[test]
    fn zero_budget_disables() {
        assert!(FillCache::new(0, 2, 4, 2).is_none());
    }

    #[test]
    fn zero_sized_block_disables() {
        // A degenerate all-zero tensor shape must not produce a cache of
        // zero-byte slots (`budget / 0-bytes` used to saturate capacity).
        assert!(FillCache::new(64, 0, 0, 0).is_none());
    }

    #[test]
    fn generations_are_isolated() {
        let c = tiny();
        let (mut n, mut a, mut m) = ([0f32; 2], [0f32; 4], [0f32; 2]);
        c.put(1, 7, &[1.0; 2], &[1.0; 4], &[1.0; 2]);
        // same block key under another generation: miss, not a stale hit
        assert!(!c.get(2, 7, &mut n, &mut a, &mut m));
        assert!(c.get(1, 7, &mut n, &mut a, &mut m));
    }

    #[test]
    fn shared_handles_merge_stats_and_prewarm() {
        // A deliberately odd shape so no other test's registry entry
        // collides with this one.
        let shape = (3usize, 5usize, 1usize);
        let mut h1 = FillHandle::new(1, true, shape.0, shape.1, shape.2);
        let mut h2 = FillHandle::new(1, true, shape.0, shape.1, shape.2);
        h1.bind_generation(42);
        h2.bind_generation(42);
        assert!(h1.is_enabled() && h2.is_enabled());
        let (mut n, mut a, mut m) = ([0f32; 3], [0f32; 5], [0f32; 1]);
        h1.put(9, &[1.0; 3], &[2.0; 5], &[3.0; 1]);
        // the second handle is served by the first handle's insert...
        assert!(h2.get(9, &mut n, &mut a, &mut m));
        assert_eq!(n, [1.0; 3]);
        // ...and both report the same merged counters and bytes
        assert_eq!(h1.stats(), h2.stats());
        assert_eq!(h1.stats().hits, 1);
        assert_eq!(h1.bytes(), h2.bytes());
        assert_eq!(h1.contention().len(), 1);
        // a private handle of the same shape is its own cache
        let mut h3 = FillHandle::new(1, false, shape.0, shape.1, shape.2);
        h3.bind_generation(42);
        assert!(!h3.get(9, &mut n, &mut a, &mut m));
    }

    #[test]
    fn disabled_handle_is_inert() {
        let h = FillHandle::disabled();
        let (mut n, mut a, mut m) = ([0f32; 2], [0f32; 4], [0f32; 2]);
        assert!(!h.is_enabled());
        assert!(!h.get(1, &mut n, &mut a, &mut m));
        h.put(1, &[0.0; 2], &[0.0; 4], &[0.0; 2]);
        assert_eq!(h.stats(), CacheStats::default());
        assert_eq!(h.bytes(), 0);
        assert!(h.contention().is_empty());
    }

    #[test]
    fn roundtrip_and_counters() {
        let c = tiny();
        let (mut n, mut a, mut m) = ([9f32; 2], [9f32; 4], [9f32; 2]);
        assert!(!c.get(0, 7, &mut n, &mut a, &mut m));
        c.put(0, 7, &[1.0, 2.0], &[3.0, 4.0, 5.0, 6.0], &[1.0, 0.0]);
        assert!(c.get(0, 7, &mut n, &mut a, &mut m));
        assert_eq!(n, [1.0, 2.0]);
        assert_eq!(a, [3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m, [1.0, 0.0]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        // one resident (2 + 4 + 2)-float block
        assert_eq!(c.bytes(), 32);
    }

    #[test]
    fn put_refreshes_existing_entry() {
        let c = tiny();
        let (mut n, mut a, mut m) = ([0f32; 2], [0f32; 4], [0f32; 2]);
        c.put(0, 1, &[1.0; 2], &[1.0; 4], &[1.0; 2]);
        c.put(0, 1, &[2.0; 2], &[2.0; 4], &[2.0; 2]);
        assert_eq!(c.len(), 1);
        assert!(c.get(0, 1, &mut n, &mut a, &mut m));
        assert_eq!(n, [2.0; 2]);
    }

    #[test]
    fn clock_eviction_keeps_hot_entries() {
        // capacity-sized exactly: blocks of 8 floats (32 B), 1 MiB budget
        // holds plenty, so build a cache whose capacity we then saturate
        let c = FillCache::new(1, 2, 4, 2).unwrap();
        let cap = c.capacity();
        let (mut n, mut a, mut m) = ([0f32; 2], [0f32; 4], [0f32; 2]);
        for k in 0..cap as u64 {
            c.put(0, k, &[k as f32; 2], &[0.0; 4], &[0.0; 2]);
        }
        assert_eq!(c.len(), cap);
        // touch key 0 (sets its reference bit), then insert a new key:
        // the sweep must skip the hot entry and evict a cold one
        assert!(c.get(0, 0, &mut n, &mut a, &mut m));
        c.put(0, cap as u64, &[7.0; 2], &[0.0; 4], &[0.0; 2]);
        assert_eq!(c.len(), cap);
        assert!(c.get(0, 0, &mut n, &mut a, &mut m), "hot entry evicted");
        assert!(c.get(0, cap as u64, &mut n, &mut a, &mut m));
    }

    #[test]
    fn eviction_is_bounded_by_capacity() {
        let c = FillCache::new(1, 2, 4, 2).unwrap();
        let cap = c.capacity();
        for k in 0..(cap as u64) * 3 {
            c.put(0, k, &[k as f32; 2], &[0.0; 4], &[0.0; 2]);
        }
        assert_eq!(c.len(), cap);
    }
}
