//! End-to-end trainer integration over the real AOT artifacts: every
//! method on a small synthetic MalNet, plus the TpuGraphs ranking path.

use gst::datasets::{MalnetDataset, MalnetSplit, TpuDataset};
use gst::partition::Algorithm;
use gst::runtime::Engine;
use gst::train::{MalnetTrainer, Method, TpuTrainer, TrainConfig};

fn dir(v: &str) -> Option<String> {
    let d = format!("{}/artifacts/{v}", env!("CARGO_MANIFEST_DIR"));
    std::path::Path::new(&d).is_dir().then_some(d)
}

fn quick_cfg(method: Method) -> TrainConfig {
    TrainConfig {
        method,
        epochs: 2,
        finetune_epochs: 1,
        eval_every: 2,
        seed: 1,
        ..TrainConfig::default()
    }
}

#[test]
fn every_method_trains_on_malnet_tiny() {
    let Some(d) = dir("malnet_sage_n128") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let eng = Engine::open(&d).unwrap();
    let data = MalnetDataset::generate(MalnetSplit::Tiny, 40, 3);
    for method in [
        Method::FullGraph,
        Method::Gst,
        Method::GstOne,
        Method::GstE,
        Method::GstEFD,
    ] {
        let mut tr =
            MalnetTrainer::new(&eng, &data, quick_cfg(method)).unwrap();
        let res = tr.train().unwrap_or_else(|e| panic!("{method:?}: {e}"));
        assert!(
            res.test_metric.is_finite() && res.test_metric >= 0.0,
            "{method:?}"
        );
        assert!(res.step_ms > 0.0, "{method:?} recorded no steps");
        assert!(!res.curve.epochs.is_empty());
    }
}

#[test]
fn table_methods_fill_the_table() {
    let Some(d) = dir("malnet_sage_n128") else {
        return;
    };
    let eng = Engine::open(&d).unwrap();
    let data = MalnetDataset::generate(MalnetSplit::Tiny, 30, 5);
    let mut tr =
        MalnetTrainer::new(&eng, &data, quick_cfg(Method::GstE)).unwrap();
    assert_eq!(tr.table.coverage(), 0.0);
    tr.train().unwrap();
    // every training-graph segment sampled or cold-read at least once in
    // two epochs -> coverage well above zero (test graphs are never written)
    assert!(tr.table.coverage() > 0.2, "coverage {}", tr.table.coverage());
}

#[test]
fn gst_does_more_embed_calls_than_gst_e() {
    let Some(d) = dir("malnet_sage_n128") else {
        return;
    };
    let data = MalnetDataset::generate(MalnetSplit::Tiny, 30, 7);
    let count = |method: Method| {
        let eng = Engine::open(&d).unwrap();
        let mut cfg = quick_cfg(method);
        cfg.eval_every = 99; // isolate the training loop from eval calls
        let mut tr = MalnetTrainer::new(&eng, &data, cfg).unwrap();
        tr.train().unwrap();
        *eng.call_counts().get("embed_fwd").unwrap_or(&0)
    };
    let gst = count(Method::Gst);
    let gste = count(Method::GstE);
    assert!(
        gst > gste,
        "GST should recompute stale segments every step: {gst} vs {gste}"
    );
}

#[test]
fn full_graph_ooms_on_large_graphs() {
    let Some(d) = dir("malnet_sage_n128") else {
        return;
    };
    let eng = Engine::open(&d).unwrap();
    // the large split has graphs with far more than full_jmax segments
    let data = MalnetDataset::generate(MalnetSplit::Large, 10, 1);
    let err = MalnetTrainer::new(&eng, &data, quick_cfg(Method::FullGraph))
        .err()
        .expect("must OOM");
    assert!(err.to_string().contains("OOM"), "{err}");
}

#[test]
fn determinism_same_seed_same_result() {
    let Some(d) = dir("malnet_sage_n128") else {
        return;
    };
    let eng = Engine::open(&d).unwrap();
    let data = MalnetDataset::generate(MalnetSplit::Tiny, 30, 9);
    let run = || {
        let mut tr =
            MalnetTrainer::new(&eng, &data, quick_cfg(Method::GstEFD))
                .unwrap();
        tr.train().unwrap().test_metric
    };
    assert_eq!(run(), run());
}

#[test]
fn tpu_ranking_trains_and_scores_opa() {
    let Some(d) = dir("tpu_sage_n128") else {
        eprintln!("skipping: tpu artifacts not built");
        return;
    };
    let eng = Engine::open(&d).unwrap();
    let data = TpuDataset::generate(6, 6, 11);
    let mut cfg = quick_cfg(Method::GstED);
    cfg.partition = Algorithm::MetisLike;
    let mut tr = TpuTrainer::new(&eng, &data, cfg).unwrap();
    let res = tr.train().unwrap();
    assert!((0.0..=1.0).contains(&res.test_metric), "{}", res.test_metric);
    assert!(res.step_ms > 0.0);
}

#[test]
fn tpu_rejects_full_graph() {
    let Some(d) = dir("tpu_sage_n128") else {
        return;
    };
    let eng = Engine::open(&d).unwrap();
    let data = TpuDataset::generate(2, 2, 1);
    let err = TpuTrainer::new(&eng, &data, quick_cfg(Method::FullGraph))
        .err()
        .expect("must OOM");
    assert!(err.to_string().contains("OOM"));
}
