//! PJRT executable cache + tensor marshalling.
//!
//! One [`Engine`] per artifact variant: it owns the PJRT CPU client, lazily
//! compiles each HLO-text function on first use, and executes with plain
//! `Vec<f32>`/`Vec<i32>` host tensors. All outputs come back as host
//! vectors (loss scalars, gradients, embeddings) — the coordinator is the
//! state owner, which is what lets it average gradients across data-parallel
//! workers and write embeddings into the table.
//!
//! `Engine` is `Sync`: the executable cache is behind a [`TimedRwLock`]
//! (writes only on first compile; every steady-state call takes the read
//! lock) and the call counters behind a [`TimedMutex`], so `GstCore`'s
//! worker threads execute micro-batches through one shared engine
//! concurrently — and [`Engine::lock_stats`] reports how long they
//! actually blocked on each other doing it.
//!
//! The engine also caches marshalled **parameter literals** per
//! [`ParamStore`] (keyed by [`ParamStore::cache_key`]): the dozens of
//! `embed_fwd`/`grad_step` calls within one optimizer step share the same
//! parameters, so [`Engine::call_with_params`] marshals them once per
//! generation instead of per call. Execution-only — the literal contents
//! are identical either way.

use super::manifest::{Dtype, FnSpec, Manifest, TensorSpec};
use super::params::ParamStore;
use crate::metrics::CacheStats;
use crate::util::sync::{LockStats, TimedMutex, TimedRwLock};
use anyhow::{anyhow, bail, Result};
use std::borrow::Borrow;
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// A host-side tensor heading into (or out of) an executable.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>),
    S32(Vec<i32>),
}

impl HostTensor {
    pub fn f32s(&self) -> &[f32] {
        match self {
            HostTensor::F32(v) => v,
            HostTensor::S32(_) => panic!("expected f32 tensor"),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::S32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl From<Vec<f32>> for HostTensor {
    fn from(v: Vec<f32>) -> Self {
        HostTensor::F32(v)
    }
}

impl From<Vec<i32>> for HostTensor {
    fn from(v: Vec<i32>) -> Self {
        HostTensor::S32(v)
    }
}

/// Borrowed input view — the zero-clone fast path for the training loop
/// (the only remaining host copy is the literal construction itself).
#[derive(Clone, Copy, Debug)]
pub enum HostArg<'a> {
    F32(&'a [f32]),
    S32(&'a [i32]),
}

impl<'a> HostArg<'a> {
    fn len(&self) -> usize {
        match self {
            HostArg::F32(v) => v.len(),
            HostArg::S32(v) => v.len(),
        }
    }
}

impl<'a> From<&'a HostTensor> for HostArg<'a> {
    fn from(t: &'a HostTensor) -> Self {
        match t {
            HostTensor::F32(v) => HostArg::F32(v),
            HostTensor::S32(v) => HostArg::S32(v),
        }
    }
}

thread_local! {
    /// Per-thread staging for marshalled input literals. The Vec (and,
    /// through the xla buffer pools, the literals' storage) survives
    /// across calls, so steady-state input marshalling allocates nothing.
    static LIT_SCRATCH: RefCell<Vec<xla::Literal>> =
        RefCell::new(Vec::new());
    /// Per-thread staging for a spec's shape-as-i64 dims.
    static DIMS_SCRATCH: RefCell<Vec<i64>> = RefCell::new(Vec::new());
}

/// Run `f` over this thread's (cleared) literal staging buffer.
///
/// Not reentrant: `f` must not trigger another engine call on the same
/// thread (engine calls never nest — the only marshalling done inside,
/// [`Engine::param_literals`], builds its own owned vector).
fn with_lit_scratch<R>(f: impl FnOnce(&mut Vec<xla::Literal>) -> R) -> R {
    LIT_SCRATCH.with(|s| {
        let mut lits = s.borrow_mut();
        lits.clear();
        f(&mut lits)
    })
}

/// Parameter-literal cache entry: (store generation, shared literal set).
type ParamLitEntry = (u64, Arc<Vec<xla::Literal>>);

/// Per-function execution accounting: invocation count and cumulative
/// wall-clock (execute + output unmarshal) in nanoseconds.
#[derive(Clone, Copy, Debug, Default)]
struct CallStat {
    count: usize,
    ns: u64,
}

/// Executable cache for one artifact variant.
pub struct Engine {
    pub manifest: Manifest,
    dir: String,
    client: xla::PjRtClient,
    exes: TimedRwLock<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// cumulative executions + wall-clock per function (observability
    /// and perf accounting)
    calls: TimedMutex<HashMap<String, CallStat>>,
    /// cumulative bytes marshalled into input literals (positional
    /// inputs + parameter-literal rebuilds)
    marshal_bytes: AtomicU64,
    /// marshalled parameter literals per store id, tagged with the store
    /// generation they were built from
    param_lits: TimedRwLock<HashMap<u64, ParamLitEntry>>,
    param_hits: AtomicU64,
    param_misses: AtomicU64,
}

impl Engine {
    /// Open an artifact directory (compiles nothing yet).
    pub fn open(dir: &str) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine {
            manifest,
            dir: dir.to_string(),
            client,
            exes: TimedRwLock::new(HashMap::new()),
            calls: TimedMutex::new(HashMap::new()),
            marshal_bytes: AtomicU64::new(0),
            param_lits: TimedRwLock::new(HashMap::new()),
            param_hits: AtomicU64::new(0),
            param_misses: AtomicU64::new(0),
        })
    }

    /// Compile (and cache) one function's HLO text. Racing threads may
    /// both compile; the first insert wins and the duplicate is dropped.
    fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.exes.read().contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.func(name)?;
        let path = format!("{}/{}", self.dir, spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.exes.write().entry(name.to_string()).or_insert(exe);
        Ok(())
    }

    /// Pre-compile a set of functions (so timing loops exclude compilation).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.ensure_compiled(n)?;
        }
        Ok(())
    }

    /// Execute `name` with positional inputs matching the manifest specs.
    /// Returns one host tensor per manifest output. (Owning-input wrapper
    /// over [`Engine::call_ref`].)
    pub fn call(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let args: Vec<HostArg> = inputs.iter().map(HostArg::from).collect();
        self.call_ref(name, &args)
    }

    /// Execute with borrowed inputs — the training hot path. The spec is
    /// borrowed for the duration of the call (no per-call clone).
    pub fn call_ref(&self, name: &str, inputs: &[HostArg]) -> Result<Vec<HostTensor>> {
        self.ensure_compiled(name)?;
        let spec = self.manifest.func(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: {} inputs given, manifest wants {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        let bytes: usize = inputs.iter().map(|t| t.len() * 4).sum();
        self.marshal_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        with_lit_scratch(|literals| {
            for (t, ispec) in inputs.iter().zip(&spec.inputs) {
                literals.push(marshal(name, ispec, t)?);
            }
            self.execute_marshalled(name, spec, &literals[..])
        })
    }

    /// Execute `name` whose leading inputs are `ps`'s parameter set,
    /// serving the parameter literals from the per-store cache (keyed by
    /// [`ParamStore::cache_key`]; invalidated by [`ParamStore::touch`]).
    /// `rest` holds the remaining positional inputs.
    pub fn call_with_params(
        &self,
        name: &str,
        ps: &ParamStore,
        rest: &[HostArg],
    ) -> Result<Vec<HostTensor>> {
        self.ensure_compiled(name)?;
        let spec = self.manifest.func(name)?;
        let np = ps.values.len();
        if np + rest.len() != spec.inputs.len() {
            bail!(
                "{name}: {np} params + {} inputs given, manifest wants {}",
                rest.len(),
                spec.inputs.len()
            );
        }
        // resolve the cached parameter literals BEFORE borrowing the
        // scratch (a cache rebuild marshals, which must not nest into it)
        let params = self.param_literals(name, spec, ps)?;
        let bytes: usize = rest.iter().map(|t| t.len() * 4).sum();
        self.marshal_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        with_lit_scratch(|tail| {
            for (t, ispec) in rest.iter().zip(&spec.inputs[np..]) {
                tail.push(marshal(name, ispec, t)?);
            }
            let mut args: Vec<&xla::Literal> =
                Vec::with_capacity(np + tail.len());
            args.extend(params.iter());
            args.extend(tail.iter());
            self.execute_marshalled(name, spec, &args)
        })
    }

    /// Fetch (or build) the marshalled parameter literals for `ps`.
    /// Cached per store id; rebuilt whenever the store generation moved.
    /// All parameter-leading functions share one entry — the manifest
    /// orders every function's leading inputs identically.
    fn param_literals(
        &self,
        name: &str,
        spec: &FnSpec,
        ps: &ParamStore,
    ) -> Result<Arc<Vec<xla::Literal>>> {
        let (id, gen) = ps.cache_key();
        if let Some((cached_gen, lits)) = self.param_lits.read().get(&id)
        {
            if *cached_gen == gen && lits.len() == ps.values.len() {
                self.param_hits.fetch_add(1, Ordering::Relaxed);
                return Ok(lits.clone());
            }
        }
        self.param_misses.fetch_add(1, Ordering::Relaxed);
        let bytes: usize = ps.values.iter().map(|v| v.len() * 4).sum();
        self.marshal_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        let mut lits = Vec::with_capacity(ps.values.len());
        for (v, ispec) in ps.values.iter().zip(&spec.inputs) {
            lits.push(marshal(name, ispec, &HostArg::F32(v))?);
        }
        let lits = Arc::new(lits);
        self.param_lits.write().insert(id, (gen, lits.clone()));
        Ok(lits)
    }

    /// Shared execution tail: count + time the call, run the executable
    /// over already-marshalled literals, unmarshal + validate outputs.
    fn execute_marshalled<L: Borrow<xla::Literal>>(
        &self,
        name: &str,
        spec: &FnSpec,
        literals: &[L],
    ) -> Result<Vec<HostTensor>> {
        {
            // get_mut-first so the steady state (key present) skips the
            // entry-API key allocation
            let mut calls = self.calls.lock();
            if let Some(stat) = calls.get_mut(name) {
                stat.count += 1;
            } else {
                calls
                    .insert(name.to_string(), CallStat { count: 1, ns: 0 });
            }
        }
        let t0 = Instant::now();
        let exes = self.exes.read();
        let exe = exes.get(name).expect("ensured above");
        let result = exe
            .execute(literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple, even arity 1
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "{name}: {} outputs, manifest wants {}",
                parts.len(),
                spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.into_iter().zip(&spec.outputs) {
            let t = match ospec.dtype {
                Dtype::F32 => HostTensor::F32(
                    lit.to_vec::<f32>()
                        .map_err(|e| anyhow!("{name} out: {e:?}"))?,
                ),
                Dtype::S32 => HostTensor::S32(
                    lit.to_vec::<i32>()
                        .map_err(|e| anyhow!("{name} out: {e:?}"))?,
                ),
            };
            if t.len() != ospec.elems() {
                bail!(
                    "{name}:{}: got {} elems, spec {:?}",
                    ospec.name,
                    t.len(),
                    ospec.shape
                );
            }
            out.push(t);
        }
        let ns = t0.elapsed().as_nanos() as u64;
        if let Some(stat) = self.calls.lock().get_mut(name) {
            stat.ns += ns;
        }
        Ok(out)
    }

    /// Per-function call counts since construction.
    pub fn call_counts(&self) -> HashMap<String, usize> {
        self.calls
            .lock()
            .iter()
            .map(|(k, s)| (k.clone(), s.count))
            .collect()
    }

    /// Per-function cumulative wall-clock (execute + output unmarshal)
    /// in milliseconds since construction.
    pub fn call_ms(&self) -> HashMap<String, f64> {
        self.calls
            .lock()
            .iter()
            .map(|(k, s)| (k.clone(), s.ns as f64 / 1e6))
            .collect()
    }

    /// Contention counters of every engine-internal lock, keyed for the
    /// run report's `contention` section.
    pub fn lock_stats(&self) -> Vec<(String, LockStats)> {
        vec![
            ("engine.exes".to_string(), self.exes.stats()),
            ("engine.calls".to_string(), self.calls.stats()),
            ("engine.param_lits".to_string(), self.param_lits.stats()),
        ]
    }

    /// Total blocked lock-wait across the engine's locks, in ns.
    pub fn lock_wait_ns(&self) -> u64 {
        self.lock_stats().iter().map(|(_, s)| s.wait_ns).sum()
    }

    /// Total bytes marshalled into input literals (positional inputs
    /// plus parameter-literal cache rebuilds).
    pub fn marshalled_bytes(&self) -> u64 {
        self.marshal_bytes.load(Ordering::Relaxed)
    }

    /// Hit/miss counters of the parameter-literal cache.
    pub fn param_cache_stats(&self) -> CacheStats {
        CacheStats {
            hits: self.param_hits.load(Ordering::Relaxed),
            misses: self.param_misses.load(Ordering::Relaxed),
        }
    }

    pub fn dir(&self) -> &str {
        &self.dir
    }
}

/// Marshal one host argument against its input spec.
fn marshal(
    name: &str,
    ispec: &TensorSpec,
    t: &HostArg,
) -> Result<xla::Literal> {
    if t.len() != ispec.elems() {
        bail!(
            "{name}:{}: {} elems given, spec wants {:?}",
            ispec.name,
            t.len(),
            ispec.shape
        );
    }
    DIMS_SCRATCH.with(|scratch| {
        let mut dims = scratch.borrow_mut();
        dims.clear();
        dims.extend(ispec.shape.iter().map(|&d| d as i64));
        match (t, ispec.dtype) {
            (HostArg::F32(v), Dtype::F32) => {
                reshape_or_scalar(xla::Literal::vec1(v), &dims, v.len())
            }
            (HostArg::S32(v), Dtype::S32) => {
                reshape_or_scalar(xla::Literal::vec1(v), &dims, v.len())
            }
            _ => bail!("{name}:{}: dtype mismatch", ispec.name),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The property `GstCore`'s fork-join worker path depends on: one
    /// engine shared by reference across worker threads.
    #[test]
    fn engine_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Engine>();
    }
}

fn reshape_or_scalar(
    lit: xla::Literal,
    dims: &[i64],
    len: usize,
) -> Result<xla::Literal> {
    if dims.is_empty() {
        if len != 1 {
            bail!("scalar spec but {len} elems");
        }
        // rank-0: reshape to [] is valid
        lit.reshape(&[]).map_err(|e| anyhow!("reshape scalar: {e:?}"))
    } else {
        lit.reshape(dims).map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
    }
}
