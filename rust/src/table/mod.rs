//! The historical embedding table 𝒯 : (graph i, segment j) → ℝ^d.
//!
//! GST+E's core data structure (paper §3.2): stores the last computed
//! embedding of every graph segment together with the training step at
//! which it was produced, so the trainer can (a) serve stale embeddings
//! without recomputation and (b) quantify staleness — the most outdated
//! entry is ≈ n·J/S steps old (paper §3.4), which the `staleness`
//! histogram makes observable.
//!
//! Storage is a flat dense arena (graph → segment → d floats) sized once
//! from the dataset's segment counts; reads hand out slices (no copies) and
//! writes are in-place — the table is the only cross-iteration state besides
//! model parameters, and keeping it flat makes the +F full refresh a single
//! sequential sweep.

/// Dense per-segment embedding store with version tracking.
pub struct EmbeddingTable {
    dim: usize,
    /// start offset (in vectors) of each graph's segment block
    graph_off: Vec<u32>,
    data: Vec<f32>,
    /// step at which each vector was last written; u32::MAX = never
    version: Vec<u32>,
}

pub const NEVER: u32 = u32::MAX;

impl EmbeddingTable {
    /// `seg_counts[i]` = number of segments of graph i.
    pub fn new(seg_counts: &[usize], dim: usize) -> EmbeddingTable {
        let mut graph_off = Vec::with_capacity(seg_counts.len() + 1);
        graph_off.push(0u32);
        for &c in seg_counts {
            graph_off.push(graph_off.last().unwrap() + c as u32);
        }
        let total = *graph_off.last().unwrap() as usize;
        EmbeddingTable {
            dim,
            graph_off,
            data: vec![0.0; total * dim],
            version: vec![NEVER; total],
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn num_graphs(&self) -> usize {
        self.graph_off.len() - 1
    }

    pub fn segments_of(&self, graph: usize) -> usize {
        (self.graph_off[graph + 1] - self.graph_off[graph]) as usize
    }

    fn slot(&self, graph: usize, seg: usize) -> usize {
        debug_assert!(seg < self.segments_of(graph));
        self.graph_off[graph] as usize + seg
    }

    /// Read the embedding — `None` until the first write.
    pub fn get(&self, graph: usize, seg: usize) -> Option<&[f32]> {
        let s = self.slot(graph, seg);
        if self.version[s] == NEVER {
            None
        } else {
            Some(&self.data[s * self.dim..(s + 1) * self.dim])
        }
    }

    /// Age (in steps) of the entry at `now`, or `None` if never written.
    /// Saturating: a snapshot taken with a step counter behind a
    /// just-committed write (`now < version`) reports age 0 instead of
    /// wrapping to ~4e9 and poisoning the staleness histogram.
    pub fn staleness(&self, graph: usize, seg: usize, now: u32) -> Option<u32> {
        let s = self.slot(graph, seg);
        (self.version[s] != NEVER)
            .then(|| now.saturating_sub(self.version[s]))
    }

    /// InsertOrUpdate (Alg. 2 line 7): write-back after a forward pass.
    pub fn put(&mut self, graph: usize, seg: usize, h: &[f32], step: u32) {
        assert_eq!(h.len(), self.dim);
        let s = self.slot(graph, seg);
        self.data[s * self.dim..(s + 1) * self.dim].copy_from_slice(h);
        self.version[s] = step;
    }

    /// Flat arena index of `(graph, seg)` — lets a batched committer
    /// sort write-backs into contiguous slot runs before copying.
    pub fn slot_index(&self, graph: usize, seg: usize) -> usize {
        self.slot(graph, seg)
    }

    /// Total number of slots in the arena.
    pub fn num_slots(&self) -> usize {
        self.version.len()
    }

    /// Batched write-back: store `h` (k·dim floats) into the k
    /// consecutive slots starting at `slot0`, all versioned `step` —
    /// one contiguous copy instead of k row copies.
    pub fn put_run(&mut self, slot0: usize, h: &[f32], step: u32) {
        assert_eq!(h.len() % self.dim, 0);
        let k = h.len() / self.dim;
        assert!(slot0 + k <= self.version.len());
        self.data[slot0 * self.dim..(slot0 + k) * self.dim]
            .copy_from_slice(h);
        self.version[slot0..slot0 + k].fill(step);
    }

    /// Fraction of entries ever written — 1.0 after the first full epoch.
    pub fn coverage(&self) -> f64 {
        if self.version.is_empty() {
            return 1.0;
        }
        let written =
            self.version.iter().filter(|&&v| v != NEVER).count();
        written as f64 / self.version.len() as f64
    }

    /// Visit the age (at `now`) of every written entry — the telemetry
    /// walk shared by [`EmbeddingTable::mean_staleness`] and the
    /// per-epoch staleness histogram (no per-call age buffer). Ages
    /// saturate at 0 like [`EmbeddingTable::staleness`].
    pub fn for_each_staleness<F: FnMut(u32)>(&self, now: u32, mut f: F) {
        for &v in &self.version {
            if v != NEVER {
                f(now.saturating_sub(v));
            }
        }
    }

    /// Mean staleness over written entries at `now` (0.0 when none).
    pub fn mean_staleness(&self, now: u32) -> f64 {
        let mut sum = 0f64;
        let mut count = 0usize;
        self.for_each_staleness(now, |age| {
            sum += age as f64;
            count += 1;
        });
        if count == 0 {
            0.0
        } else {
            sum / count as f64
        }
    }

    /// Bytes held by the table (the "memory overhead" the paper trades for
    /// the 3× speedup — reported in the Table 3 experiment).
    pub fn bytes(&self) -> usize {
        self.data.len() * 4 + self.version.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> EmbeddingTable {
        EmbeddingTable::new(&[3, 1, 2], 4)
    }

    #[test]
    fn layout_and_lookup() {
        let mut t = table();
        assert_eq!(t.num_graphs(), 3);
        assert_eq!(t.segments_of(0), 3);
        assert_eq!(t.segments_of(2), 2);
        assert!(t.get(0, 0).is_none());
        t.put(0, 2, &[1.0, 2.0, 3.0, 4.0], 10);
        assert_eq!(t.get(0, 2).unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert!(t.get(0, 1).is_none());
        // neighbor slots untouched
        t.put(1, 0, &[9.0; 4], 11);
        assert_eq!(t.get(0, 2).unwrap(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn staleness_tracks_steps() {
        let mut t = table();
        t.put(0, 0, &[0.0; 4], 5);
        assert_eq!(t.staleness(0, 0, 25), Some(20));
        assert_eq!(t.staleness(0, 1, 25), None);
        t.put(0, 0, &[0.0; 4], 24);
        assert_eq!(t.staleness(0, 0, 25), Some(1));
    }

    #[test]
    fn staleness_saturates_when_snapshot_lags_a_write() {
        // regression: a snapshot taken with `now` behind a just-committed
        // version used to wrap `now - version` to ~4e9
        let mut t = table();
        t.put(0, 0, &[0.0; 4], 10);
        t.put(1, 0, &[0.0; 4], 2);
        assert_eq!(t.staleness(0, 0, 7), Some(0));
        let mut ages = Vec::new();
        t.for_each_staleness(7, |age| ages.push(age));
        ages.sort_unstable();
        assert_eq!(ages, vec![0, 5]);
        assert!((t.mean_staleness(7) - 2.5).abs() < 1e-9);
        // a genuinely old entry is unaffected
        assert_eq!(t.staleness(0, 0, 25), Some(15));
    }

    #[test]
    fn coverage_progression() {
        let mut t = table();
        assert_eq!(t.coverage(), 0.0);
        t.put(0, 0, &[0.0; 4], 0);
        t.put(0, 1, &[0.0; 4], 0);
        t.put(0, 2, &[0.0; 4], 0);
        assert!((t.coverage() - 0.5).abs() < 1e-9);
        t.put(1, 0, &[0.0; 4], 0);
        t.put(2, 0, &[0.0; 4], 0);
        t.put(2, 1, &[0.0; 4], 0);
        assert_eq!(t.coverage(), 1.0);
    }

    #[test]
    fn mean_staleness() {
        let mut t = table();
        t.put(0, 0, &[0.0; 4], 0);
        t.put(1, 0, &[0.0; 4], 10);
        assert!((t.mean_staleness(20) - 15.0).abs() < 1e-9);
    }

    #[test]
    fn for_each_staleness_visits_only_written_entries() {
        let mut t = table();
        t.put(0, 0, &[0.0; 4], 0);
        t.put(1, 0, &[0.0; 4], 10);
        let mut ages = Vec::new();
        t.for_each_staleness(20, |age| ages.push(age));
        ages.sort_unstable();
        assert_eq!(ages, vec![10, 20]);
    }

    #[test]
    fn put_run_matches_row_puts() {
        let mut a = table();
        let mut b = table();
        // graph 0 has 3 segments at slots 0..3; graph 1's single segment
        // is slot 3 — a run can span the graph boundary because the
        // arena is flat.
        let h: Vec<f32> = (0..16).map(|x| x as f32).collect();
        for (k, seg) in [(0usize, 0usize), (0, 1), (0, 2)] {
            a.put(k, seg, &h[seg * 4..(seg + 1) * 4], 7);
        }
        a.put(1, 0, &h[12..16], 7);
        b.put_run(b.slot_index(0, 0), &h, 7);
        for (g, s) in [(0, 0), (0, 1), (0, 2), (1, 0)] {
            assert_eq!(a.get(g, s), b.get(g, s));
            assert_eq!(a.staleness(g, s, 9), b.staleness(g, s, 9));
        }
        // untouched slots still unwritten
        assert!(b.get(2, 0).is_none());
        assert_eq!(b.num_slots(), 6);
    }

    #[test]
    #[should_panic]
    fn put_run_out_of_range_panics() {
        let mut t = table();
        t.put_run(5, &[0.0; 8], 0);
    }

    #[test]
    #[should_panic]
    fn wrong_dim_panics() {
        let mut t = table();
        t.put(0, 0, &[0.0; 3], 0);
    }

    #[test]
    fn overwrite_updates_version_and_value() {
        let mut t = table();
        t.put(2, 1, &[1.0; 4], 1);
        t.put(2, 1, &[2.0; 4], 9);
        assert_eq!(t.get(2, 1).unwrap(), &[2.0; 4]);
        assert_eq!(t.staleness(2, 1, 10), Some(1));
    }
}
