//! Synthetic dataset generators standing in for the paper's corpora
//! (substitutions documented in DESIGN.md §2):
//!
//! * [`malnet`] — 5-class function-call-graph classification, `tiny` and
//!   `large` splits (MalNet-Tiny / MalNet-Large analogues)
//! * [`tpugraphs`] — HLO-like layered DAGs with per-node layout configs and
//!   a synthetic runtime model; ranking target (TpuGraphs analogue)
//! * [`features`] — LDP-style structural node features shared by both

pub mod features;
pub mod malnet;
pub mod tpugraphs;

pub use malnet::{MalnetDataset, MalnetSplit};
pub use tpugraphs::{TpuDataset, TpuGraph};
