//! Minimal offline stand-in for the `xla` crate (xla_extension bindings).
//!
//! The build image has no PJRT shared library, so this crate provides the
//! exact type/function surface `gst::runtime::engine` compiles against:
//! host-side [`Literal`] marshalling works for real, while `compile` /
//! `execute` return a descriptive error. The coordinator's artifact-gated
//! tests and benches detect the missing `artifacts/` directory and skip
//! before ever reaching those calls, so `cargo test` is fully green against
//! this stub. Swapping the `xla` path dependency for a real xla-rs checkout
//! restores execution with zero source changes.
//!
//! Every type here is plain host data (no FFI handles), which also makes
//! the whole crate `Send + Sync` — the property `Engine: Sync` relies on.

use std::cell::RefCell;
use std::fmt;

/// Per-thread buffer pools backing [`Literal`] construction, clone and
/// drop. Literals churn once per engine input per call in the training
/// hot path; recycling their buffers makes steady-state marshalling
/// allocation-free once each pooled vector has grown to its working
/// capacity. Bounded per thread (`POOL_CAP` buffers per element type).
const POOL_CAP: usize = 32;

thread_local! {
    static F32_POOL: RefCell<Vec<Vec<f32>>> = RefCell::new(Vec::new());
    static S32_POOL: RefCell<Vec<Vec<i32>>> = RefCell::new(Vec::new());
    static DIMS_POOL: RefCell<Vec<Vec<i64>>> = RefCell::new(Vec::new());
}

macro_rules! pool_fns {
    ($take:ident, $give:ident, $pool:ident, $t:ty) => {
        fn $take() -> Vec<$t> {
            $pool.with(|p| p.borrow_mut().pop()).unwrap_or_default()
        }

        fn $give(mut v: Vec<$t>) {
            if v.capacity() == 0 {
                return;
            }
            v.clear();
            $pool.with(|p| {
                let mut p = p.borrow_mut();
                if p.len() < POOL_CAP {
                    p.push(v);
                }
            });
        }
    };
}

pool_fns!(take_f32, give_f32, F32_POOL, f32);
pool_fns!(take_s32, give_s32, S32_POOL, i32);
pool_fns!(take_dims, give_dims, DIMS_POOL, i64);

/// Error type mirroring xla-rs's; engine code formats it with `{:?}`.
pub struct XlaError(pub String);

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, XlaError>;

fn stub_err(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: stub xla crate (no PJRT runtime in this build) — point \
         the `xla` path dependency at a real xla-rs checkout to execute \
         AOT artifacts"
    ))
}

/// Element types the engine marshals (everything is f32 except labels).
mod native {
    use super::Literal;

    pub trait Sealed: Copy {
        /// Copy a host slice into a pool-recycled [`super::Storage`].
        fn wrap_pooled(v: &[Self]) -> super::Storage;
        fn unwrap(lit: &Literal) -> Option<Vec<Self>>;
    }
}

/// Marker for types [`Literal::vec1`] / [`Literal::to_vec`] accept.
pub trait NativeType: native::Sealed {}

impl native::Sealed for f32 {
    fn wrap_pooled(v: &[f32]) -> Storage {
        let mut buf = take_f32();
        buf.extend_from_slice(v);
        Storage::F32(buf)
    }
    fn unwrap(lit: &Literal) -> Option<Vec<f32>> {
        match &lit.data {
            Storage::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}
impl NativeType for f32 {}

impl native::Sealed for i32 {
    fn wrap_pooled(v: &[i32]) -> Storage {
        let mut buf = take_s32();
        buf.extend_from_slice(v);
        Storage::S32(buf)
    }
    fn unwrap(lit: &Literal) -> Option<Vec<i32>> {
        match &lit.data {
            Storage::S32(v) => Some(v.clone()),
            _ => None,
        }
    }
}
impl NativeType for i32 {}

#[doc(hidden)]
#[derive(Debug)]
pub enum Storage {
    F32(Vec<f32>),
    S32(Vec<i32>),
}

impl Default for Storage {
    fn default() -> Storage {
        Storage::F32(Vec::new())
    }
}

impl Storage {
    fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::S32(v) => v.len(),
        }
    }

    /// Copy into pool-recycled buffers (the [`Literal`] clone path).
    fn clone_pooled(&self) -> Storage {
        match self {
            Storage::F32(v) => {
                let mut buf = take_f32();
                buf.extend_from_slice(v);
                Storage::F32(buf)
            }
            Storage::S32(v) => {
                let mut buf = take_s32();
                buf.extend_from_slice(v);
                Storage::S32(buf)
            }
        }
    }

    /// Hand the backing buffer to this thread's pool.
    fn recycle(self) {
        match self {
            Storage::F32(v) => give_f32(v),
            Storage::S32(v) => give_s32(v),
        }
    }
}

/// Host literal: typed buffer + dims. Fully functional (the marshalling
/// half of the engine is real even under the stub). Construction, clone
/// and drop all cycle their buffers through this thread's pools, so
/// literal churn in a steady-state loop stops allocating once the pools
/// are warm.
#[derive(Debug)]
pub struct Literal {
    data: Storage,
    dims: Vec<i64>,
}

impl Clone for Literal {
    fn clone(&self) -> Literal {
        let mut dims = take_dims();
        dims.extend_from_slice(&self.dims);
        Literal { data: self.data.clone_pooled(), dims }
    }
}

impl Drop for Literal {
    fn drop(&mut self) {
        std::mem::take(&mut self.data).recycle();
        give_dims(std::mem::take(&mut self.dims));
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        let mut dims = take_dims();
        dims.push(v.len() as i64);
        Literal { dims, data: T::wrap_pooled(v) }
    }

    /// Reshape; element count must be preserved (`[]` = scalar).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.data.len() as i64;
        if want.max(1) != have.max(1) {
            return Err(XlaError(format!(
                "reshape: {have} elems into {dims:?}"
            )));
        }
        let mut d = take_dims();
        d.extend_from_slice(dims);
        Ok(Literal { data: self.data.clone_pooled(), dims: d })
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(stub_err("to_tuple"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(self).ok_or_else(|| {
            XlaError("to_vec: literal dtype mismatch".to_string())
        })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module handle (opaque under the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Reads the file (so missing-artifact errors surface with the right
    /// path) but performs no HLO parsing under the stub.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::read_to_string(path)
            .map_err(|e| XlaError(format!("{path}: {e}")))?;
        Ok(HloModuleProto)
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err("compile"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err("to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(lit.dims(), &[4]);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[3]).is_err());
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_reshape() {
        let lit = Literal::vec1(&[5i32]);
        let s = lit.reshape(&[]).unwrap();
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![5]);
    }

    #[test]
    fn compile_fails_with_stub_message() {
        let client = PjRtClient::cpu().unwrap();
        let err = client.compile(&XlaComputation).unwrap_err();
        assert!(format!("{err:?}").contains("stub xla crate"));
    }

    #[test]
    fn pooled_buffers_are_reused() {
        // Drop a literal, then build one of the same shape: the second
        // must inherit the first's (grown) buffer from the pool.
        let data: Vec<f32> = (0..64).map(|x| x as f32).collect();
        drop(Literal::vec1(&data));
        let lit = Literal::vec1(&data);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        // clones are pooled copies, not shared storage
        let c = lit.clone();
        drop(lit);
        assert_eq!(c.to_vec::<f32>().unwrap(), data);
        assert_eq!(c.dims(), &[64]);
    }

    #[test]
    fn everything_is_send_sync() {
        fn assert_ss<T: Send + Sync>() {}
        assert_ss::<PjRtClient>();
        assert_ss::<PjRtLoadedExecutable>();
        assert_ss::<Literal>();
    }
}
