//! Synthetic TpuGraphs: HLO-like layered DAGs + layout configs + a
//! synthetic runtime model, evaluated by ranking (OPA).
//!
//! The real dataset predicts TPU runtime of an XLA HLO graph under a
//! tensor-layout configuration; the configuration is featurized into node
//! features and the model ranks configurations per graph. This generator
//! preserves exactly that structure:
//!
//! * topology: layered op DAG (op kinds: matmul, conv, elementwise, ...)
//!   with skip connections, sizes drawn heavy-tailed;
//! * config: one layout code (0..4) per *configurable* node (matmul/conv/
//!   reshape), one-hot in the last 8 feature dims;
//! * runtime label: Σ over nodes of `base_cost(kind) · size · layout_factor`
//!   plus a **producer/consumer layout-mismatch penalty** per edge (the
//!   physical analogue: a transpose copy gets inserted) plus mild noise.
//!
//! The mismatch term makes runtime a function of *interacting* node pairs,
//! so per-segment sums genuinely approximate, not equal, the true runtime —
//! which is the interesting regime for GST (cut edges lose exactly the
//! cross-segment mismatch information).

use crate::graph::{Csr, GraphBuilder};
use crate::util::rng::Pcg64;

pub const NUM_OP_KINDS: usize = 12;
pub const STATIC_DIM: usize = 16; // op one-hot (12) + log-size, fan, depth, 1
pub const CONFIG_DIM: usize = 8; // layout one-hot (5) + 3 spare
pub const FEAT_DIM: usize = STATIC_DIM + CONFIG_DIM; // 24, matches VariantConfig
pub const NUM_LAYOUTS: usize = 5;

/// Op kinds roughly mirroring HLO opcode classes.
const KIND_COST: [f32; NUM_OP_KINDS] = [
    8.0,  // 0 matmul
    10.0, // 1 conv
    1.0,  // 2 elementwise-unary
    1.5,  // 3 elementwise-binary
    2.5,  // 4 reduce
    0.8,  // 5 reshape
    1.2,  // 6 transpose
    0.6,  // 7 broadcast
    1.8,  // 8 concat
    2.2,  // 9 gather
    1.4,  // 10 slice
    0.4,  // 11 constant/param
];

/// Kinds whose layout is configurable (the paper: layouts of convolutions
/// and reshapes etc. are what the compiler config controls).
fn configurable(kind: usize) -> bool {
    matches!(kind, 0 | 1 | 5 | 6)
}

/// One HLO-like graph with its per-config layouts and measured runtimes.
pub struct TpuGraph {
    /// Static part of the features (STATIC_DIM dims); config dims zeroed.
    pub csr: Csr,
    pub kinds: Vec<u8>,
    pub sizes: Vec<f32>, // per-node tensor size factor
    /// `configs[c][v]` = layout code of node v under config c (0 if fixed).
    pub configs: Vec<Vec<u8>>,
    /// `runtimes[c]` = synthetic measured runtime of config c.
    pub runtimes: Vec<f32>,
}

pub struct TpuDataset {
    pub graphs: Vec<TpuGraph>,
    pub train: Vec<usize>,
    pub test: Vec<usize>,
}

impl TpuDataset {
    /// `count` graphs, each with `configs_per_graph` sampled configurations.
    pub fn generate(count: usize, configs_per_graph: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 0x79a1);
        let graphs: Vec<TpuGraph> = (0..count)
            .map(|_| TpuGraph::generate(configs_per_graph, &mut rng))
            .collect();
        let mut idx: Vec<usize> = (0..count).collect();
        rng.shuffle(&mut idx);
        let ntr = count * 8 / 10;
        TpuDataset {
            graphs,
            train: idx[..ntr].to_vec(),
            test: idx[ntr..].to_vec(),
        }
    }
}

impl TpuGraph {
    pub fn generate(num_configs: usize, rng: &mut Pcg64) -> TpuGraph {
        // layered DAG: depth 8-40 layers, width 8-160, sizes heavy-tailed
        let depth = 8 + rng.below(33);
        let width = 8 + rng.below(153);
        let mut layer_of = Vec::new();
        let mut layers: Vec<Vec<usize>> = vec![Vec::new(); depth];
        for l in 0..depth {
            let w = 1 + rng.below(width);
            for _ in 0..w {
                layers[l].push(layer_of.len());
                layer_of.push(l);
            }
        }
        let n = layer_of.len();
        let mut b = GraphBuilder::new(n, FEAT_DIM);
        let mut kinds = vec![0u8; n];
        let mut sizes = vec![0f32; n];
        for v in 0..n {
            kinds[v] = rng.below(NUM_OP_KINDS) as u8;
            sizes[v] = rng.power_law(2.0, 1.0, 64.0) as f32;
        }
        // edges: each node (layer >= 1) consumes 1-3 producers from the
        // previous layer plus occasional skip connections
        for l in 1..depth {
            for &v in &layers[l] {
                let fanin = 1 + rng.below(3);
                for _ in 0..fanin {
                    let src_layer = if rng.coin(0.15) && l >= 2 {
                        rng.below(l) // skip connection
                    } else {
                        l - 1
                    };
                    let cands = &layers[src_layer];
                    let u = cands[rng.below(cands.len())];
                    b.add_edge(u, v);
                }
            }
        }
        // static features
        let max_fan = 6.0f32;
        let mut g = b.build();
        for v in 0..n {
            let mut f = vec![0f32; FEAT_DIM];
            f[kinds[v] as usize] = 1.0;
            f[12] = sizes[v].ln();
            f[13] = (g.degree(v) as f32 / max_fan).min(1.0);
            f[14] = layer_of[v] as f32 / depth as f32;
            f[15] = 1.0;
            let row = v * FEAT_DIM;
            g.feats[row..row + FEAT_DIM].copy_from_slice(&f);
        }
        // configs + runtimes
        let mut configs = Vec::with_capacity(num_configs);
        let mut runtimes = Vec::with_capacity(num_configs);
        for _ in 0..num_configs {
            let cfg: Vec<u8> = (0..n)
                .map(|v| {
                    if configurable(kinds[v] as usize) {
                        rng.below(NUM_LAYOUTS) as u8
                    } else {
                        0
                    }
                })
                .collect();
            let rt = synthetic_runtime(&g, &kinds, &sizes, &cfg, rng);
            configs.push(cfg);
            runtimes.push(rt);
        }
        TpuGraph { csr: g, kinds, sizes, configs, runtimes }
    }

    /// Bake config `c`'s layout one-hot into a copy of the static features
    /// (dims STATIC_DIM..). This is what segment padding feeds the model.
    pub fn features_for_config(&self, c: usize) -> Vec<f32> {
        let n = self.csr.num_nodes();
        let mut feats = self.csr.feats.clone();
        for v in 0..n {
            let code = self.configs[c][v] as usize;
            feats[v * FEAT_DIM + STATIC_DIM + code] = 1.0;
        }
        feats
    }
}

/// The synthetic cost model (the "hardware" substitute, DESIGN.md §2).
fn synthetic_runtime(
    g: &Csr,
    kinds: &[u8],
    sizes: &[f32],
    cfg: &[u8],
    rng: &mut Pcg64,
) -> f32 {
    let mut total = 0f32;
    for v in 0..g.num_nodes() {
        let kind = kinds[v] as usize;
        // layout affects compute cost of configurable ops: layout 0 is
        // optimal, others add up to 60%
        let layout_factor = if configurable(kind) {
            1.0 + 0.15 * cfg[v] as f32
        } else {
            1.0
        };
        total += KIND_COST[kind] * sizes[v] * layout_factor;
    }
    // producer/consumer layout mismatch inserts a transpose copy
    for (u, v) in g.edges() {
        let (u, v) = (u as usize, v as usize);
        if cfg[u] != cfg[v]
            && (configurable(kinds[u] as usize)
                || configurable(kinds[v] as usize))
        {
            total += 0.8 * (sizes[u].min(sizes[v]));
        }
    }
    // measurement noise ~1%
    total * (1.0 + 0.01 * rng.normal() as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = TpuDataset::generate(3, 4, 9);
        let b = TpuDataset::generate(3, 4, 9);
        for (x, y) in a.graphs.iter().zip(&b.graphs) {
            assert_eq!(x.csr, y.csr);
            assert_eq!(x.runtimes, y.runtimes);
        }
    }

    #[test]
    fn shapes_consistent() {
        let d = TpuDataset::generate(2, 6, 1);
        for g in &d.graphs {
            let n = g.csr.num_nodes();
            assert_eq!(g.kinds.len(), n);
            assert_eq!(g.configs.len(), 6);
            assert_eq!(g.runtimes.len(), 6);
            for c in &g.configs {
                assert_eq!(c.len(), n);
            }
            assert_eq!(g.csr.feat_dim, FEAT_DIM);
        }
    }

    #[test]
    fn config_features_one_hot() {
        let d = TpuDataset::generate(1, 3, 2);
        let g = &d.graphs[0];
        let feats = g.features_for_config(1);
        for v in 0..g.csr.num_nodes() {
            let cfg_slice =
                &feats[v * FEAT_DIM + STATIC_DIM..(v + 1) * FEAT_DIM];
            let ones = cfg_slice.iter().filter(|&&x| x == 1.0).count();
            assert_eq!(ones, 1, "node {v}: {cfg_slice:?}");
            assert_eq!(
                cfg_slice[g.configs[1][v] as usize], 1.0,
                "wrong position"
            );
        }
    }

    #[test]
    fn runtimes_vary_with_config() {
        let d = TpuDataset::generate(1, 8, 3);
        let rts = &d.graphs[0].runtimes;
        let min = rts.iter().cloned().fold(f32::MAX, f32::min);
        let max = rts.iter().cloned().fold(f32::MIN, f32::max);
        assert!(max > min * 1.01, "configs indistinguishable: {rts:?}");
    }

    #[test]
    fn all_positive_runtimes() {
        let d = TpuDataset::generate(2, 4, 4);
        for g in &d.graphs {
            for &rt in &g.runtimes {
                assert!(rt > 0.0);
            }
        }
    }

    #[test]
    fn split_disjoint() {
        let d = TpuDataset::generate(10, 2, 5);
        let mut all: Vec<usize> =
            d.train.iter().chain(&d.test).copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 10);
        assert_eq!(d.train.len(), 8);
    }
}
