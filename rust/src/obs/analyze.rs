//! Analysis half of the observability stack (DESIGN.md §9): turn the
//! artifacts PR 3's emit side produces — JSONL traces and
//! `gst-run-report` documents — into answers.
//!
//! Three entry points, all pure functions over [`Json`] (no I/O, so the
//! CLI, tests and CI wrap them freely):
//!
//! * [`analyze_trace`] — per-step critical path, phase self-time
//!   breakdown, span-attributed worker busy/imbalance, top-k slowest
//!   steps with phase attribution, and staleness / SED-drop drift
//!   (EWMA with threshold warnings) from the `epoch_*` trace points;
//! * [`analyze_report`] — the same drift + phase shares computed from a
//!   run-report document (v1 **or** v2 — the reader tolerates both);
//! * [`diff_reports`] — field-by-field comparison of two run reports
//!   (step p50/p95/steady-mean, phase totals, cache hit rates, worker
//!   imbalance, lock-wait totals) with a `--fail-on-regression`
//!   percentage; the CI perf-regression gate is exactly this function.

use std::collections::BTreeMap;

use crate::util::json::Json;
use crate::util::stats;

/// EWMA smoothing factor for the drift series (higher = more reactive).
const EWMA_ALPHA: f64 = 0.3;
/// Staleness drift warning: epoch mean > EWMA × this factor.
const STALENESS_DRIFT_FACTOR: f64 = 1.5;
/// SED drift warning: |epoch drop rate − EWMA| above this absolute gap.
const SED_DRIFT_ABS: f64 = 0.1;
/// Time-valued diff fields below this floor (ms) are skipped — relative
/// deltas on near-zero timings are pure noise.
const MIN_TIME_MS: f64 = 0.05;
/// Rate-valued diff fields below this floor are skipped likewise.
const MIN_RATE: f64 = 0.01;

/// Report schemas the readers accept (v1 predates the worker/contention
/// sections; every v1 field kept its meaning in v2).
pub const REPORT_SCHEMAS: [&str; 2] =
    ["gst-run-report/v1", "gst-run-report/v2"];

/// In-step leaf phases, in commit order (the remaining phases — `step`,
/// `eval`, `finetune` — are not step-internal).
const LEAF_PHASES: [&str; 5] =
    ["sample", "fill", "embed_fwd", "grad", "table_commit"];

/// Validate a run-report document's schema tag; returns it on success.
pub fn check_report_schema(doc: &Json) -> Result<&str, String> {
    let schema = doc
        .get("schema")
        .and_then(|s| s.as_str())
        .ok_or("document has no `schema` key — not a gst-run-report")?;
    if REPORT_SCHEMAS.contains(&schema) {
        Ok(schema)
    } else {
        Err(format!(
            "unsupported schema `{schema}` (accepted: {})",
            REPORT_SCHEMAS.join(", ")
        ))
    }
}

/// EWMA over `vals`, seeded with the first value.
fn ewma_series(vals: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(vals.len());
    let mut e = 0.0;
    for (i, &v) in vals.iter().enumerate() {
        e = if i == 0 { v } else { EWMA_ALPHA * v + (1.0 - EWMA_ALPHA) * e };
        out.push(e);
    }
    out
}

/// Dotted-path numeric lookup (`"steps.p50_ms"`), `None` when any hop
/// is missing or non-numeric.
fn num_at(doc: &Json, path: &str) -> Option<f64> {
    let mut cur = doc;
    for key in path.split('.') {
        cur = cur.get(key)?;
    }
    cur.as_f64()
}

// -- trace analysis ------------------------------------------------------

/// Per-step aggregate assembled from the trace's span events.
#[derive(Default)]
struct StepAgg {
    /// outer `step` span duration, µs
    dur_us: f64,
    /// in-step leaf phase totals, µs
    phase_us: BTreeMap<String, f64>,
    /// span-attributed busy per worker id, µs
    worker_us: BTreeMap<i64, f64>,
}

impl StepAgg {
    fn leaf(&self, phase: &str) -> f64 {
        self.phase_us.get(phase).copied().unwrap_or(0.0)
    }

    /// Critical path through the step's plan → parallel compute →
    /// commit structure: the serial phases in full, plus the *slowest
    /// worker's* share of the parallel compute region (untagged traces
    /// fall back to the serial sum of the compute phases).
    fn critical_us(&self) -> (f64, f64, f64) {
        let sample = self.leaf("sample");
        let commit = self.leaf("table_commit");
        let compute = if self.worker_us.is_empty() {
            self.leaf("fill") + self.leaf("embed_fwd") + self.leaf("grad")
        } else {
            self.worker_us.values().fold(0.0f64, |a, &b| a.max(b))
        };
        (sample, compute, commit)
    }

    fn dominant_phase(&self) -> (&'static str, f64) {
        let mut best = ("none", 0.0f64);
        for p in LEAF_PHASES {
            let us = self.leaf(p);
            if us > best.1 {
                best = (p, us);
            }
        }
        best
    }
}

/// Analyze a JSONL trace (the `--trace-out` stream) into a
/// `gst-trace-analysis/v1` document. Unknown event kinds are tolerated;
/// malformed JSON lines are an error (a truncated trace should be loud).
pub fn analyze_trace(text: &str, top_k: usize) -> Result<Json, String> {
    let mut spans = 0usize;
    let mut points = 0usize;
    let mut phase_tot: BTreeMap<String, (f64, u64)> = BTreeMap::new();
    let mut steps: BTreeMap<u64, StepAgg> = BTreeMap::new();
    let mut worker_tot: BTreeMap<i64, f64> = BTreeMap::new();
    // (epoch, coverage, mean staleness)
    let mut stale_epochs: Vec<(f64, f64, f64)> = Vec::new();
    // (epoch, cumulative stale_total, cumulative stale_dropped)
    let mut sed_epochs: Vec<(f64, f64, f64)> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let ev = Json::parse(line)
            .map_err(|e| format!("trace line {}: {e}", lineno + 1))?;
        match ev.get("ev").and_then(|v| v.as_str()) {
            Some("span") => {
                spans += 1;
                let phase = ev
                    .get("phase")
                    .and_then(|p| p.as_str())
                    .ok_or_else(|| {
                        format!("trace line {}: span without phase", lineno + 1)
                    })?
                    .to_string();
                let dur =
                    ev.get("dur_us").and_then(|d| d.as_f64()).unwrap_or(0.0);
                let step = ev
                    .get("step")
                    .and_then(|s| s.as_f64())
                    .unwrap_or(0.0) as u64;
                let worker = ev
                    .get("worker")
                    .and_then(|w| w.as_f64())
                    .map(|w| w as i64);
                let slot = phase_tot.entry(phase.clone()).or_insert((0.0, 0));
                slot.0 += dur;
                slot.1 += 1;
                if let Some(w) = worker {
                    *worker_tot.entry(w).or_insert(0.0) += dur;
                }
                // eval/finetune run outside steps; their `step` field is
                // whatever the counter last was — don't attribute them
                if phase != "eval" && phase != "finetune" {
                    let agg = steps.entry(step).or_default();
                    if phase == "step" {
                        agg.dur_us += dur;
                    } else {
                        *agg.phase_us.entry(phase).or_insert(0.0) += dur;
                        if let Some(w) = worker {
                            *agg.worker_us.entry(w).or_insert(0.0) += dur;
                        }
                    }
                }
            }
            Some("point") => {
                points += 1;
                let name =
                    ev.get("name").and_then(|n| n.as_str()).unwrap_or("");
                let data = ev.get("data").cloned().unwrap_or(Json::Null);
                let f = |k: &str| {
                    data.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0)
                };
                match name {
                    "epoch_staleness" => stale_epochs.push((
                        f("epoch"),
                        f("coverage"),
                        f("mean"),
                    )),
                    "epoch_sed" => sed_epochs.push((
                        f("epoch"),
                        f("stale_total"),
                        f("stale_dropped"),
                    )),
                    _ => {}
                }
            }
            _ => {}
        }
    }

    // step wall-clock stats, in step-id order
    let durs_ms: Vec<f64> =
        steps.values().map(|a| a.dur_us / 1e3).collect();
    let step_total_ms: f64 = durs_ms.iter().sum();
    let steps_json = Json::obj(vec![
        ("count", Json::num(durs_ms.len() as f64)),
        ("total_ms", Json::num(step_total_ms)),
        ("mean_ms", Json::num(stats::mean(&durs_ms))),
        ("p50_ms", Json::num(stats::percentile(&durs_ms, 50.0))),
        ("p95_ms", Json::num(stats::percentile(&durs_ms, 95.0))),
        ("max_ms", Json::num(stats::max(&durs_ms))),
    ]);

    // per-phase totals with share of step wall-clock
    let phases_json = Json::Obj(
        phase_tot
            .iter()
            .map(|(p, &(us, calls))| {
                let ms = us / 1e3;
                let pct = if step_total_ms > 0.0 {
                    100.0 * ms / step_total_ms
                } else {
                    0.0
                };
                (
                    p.clone(),
                    Json::obj(vec![
                        ("total_ms", Json::num(ms)),
                        ("calls", Json::num(calls as f64)),
                        ("pct_of_step", Json::num(pct)),
                    ]),
                )
            })
            .collect(),
    );

    // self-time: in-step leaves vs the step wall-clock they nest inside
    // (with >1 worker the leaf sum may exceed wall-clock; clamp at 0)
    let leaf_ms: f64 = steps
        .values()
        .map(|a| LEAF_PHASES.iter().map(|p| a.leaf(p)).sum::<f64>())
        .sum::<f64>()
        / 1e3;
    let self_json = Json::obj(vec![
        ("step_ms", Json::num(step_total_ms)),
        ("leaf_ms", Json::num(leaf_ms)),
        (
            "unattributed_ms",
            Json::num((step_total_ms - leaf_ms).max(0.0)),
        ),
    ]);

    // critical path, aggregated over steps
    let (mut cp_sample, mut cp_compute, mut cp_commit) = (0.0, 0.0, 0.0);
    for agg in steps.values() {
        let (s, c, t) = agg.critical_us();
        cp_sample += s;
        cp_compute += c;
        cp_commit += t;
    }
    let critical_ms = (cp_sample + cp_compute + cp_commit) / 1e3;
    let critical_json = Json::obj(vec![
        ("sample_ms", Json::num(cp_sample / 1e3)),
        ("compute_ms", Json::num(cp_compute / 1e3)),
        ("commit_ms", Json::num(cp_commit / 1e3)),
        ("critical_ms", Json::num(critical_ms)),
        (
            "stall_ms",
            Json::num((step_total_ms - critical_ms).max(0.0)),
        ),
    ]);

    // span-attributed worker busy (worker ids are dense from 0, but a
    // sparse map stays correct if a worker recorded nothing)
    let nworkers = worker_tot
        .keys()
        .next_back()
        .map(|&w| w as usize + 1)
        .unwrap_or(0);
    let busy_ms: Vec<f64> = (0..nworkers)
        .map(|w| {
            worker_tot.get(&(w as i64)).copied().unwrap_or(0.0) / 1e3
        })
        .collect();
    let workers_json = Json::obj(vec![
        ("count", Json::num(nworkers as f64)),
        ("busy_ms", Json::arr(busy_ms.iter().map(|&b| Json::num(b)))),
        ("imbalance_pct", Json::num(super::imbalance_pct(&busy_ms))),
    ]);

    // top-k slowest steps with dominant-phase attribution
    let mut ranked: Vec<(&u64, &StepAgg)> = steps.iter().collect();
    ranked.sort_by(|a, b| {
        b.1.dur_us
            .partial_cmp(&a.1.dur_us)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(b.0))
    });
    let top_json = Json::arr(ranked.iter().take(top_k).map(|(id, agg)| {
        let (phase, us) = agg.dominant_phase();
        let pct = if agg.dur_us > 0.0 {
            100.0 * us / agg.dur_us
        } else {
            0.0
        };
        Json::obj(vec![
            ("step", Json::num(**id as f64)),
            ("dur_ms", Json::num(agg.dur_us / 1e3)),
            ("dominant_phase", Json::str(phase)),
            ("dominant_pct", Json::num(pct)),
        ])
    }));

    let stale_means: Vec<f64> =
        stale_epochs.iter().map(|&(_, _, m)| m).collect();
    let staleness_json = staleness_drift(&stale_epochs, &stale_means);
    let sed_json = sed_drift(&sed_epochs);

    Ok(Json::obj(vec![
        ("schema", Json::str("gst-trace-analysis/v1")),
        (
            "events",
            Json::obj(vec![
                ("spans", Json::num(spans as f64)),
                ("points", Json::num(points as f64)),
            ]),
        ),
        ("steps", steps_json),
        ("phases", phases_json),
        ("self_time", self_json),
        ("critical_path", critical_json),
        ("workers", workers_json),
        ("top_steps", top_json),
        ("staleness", staleness_json),
        ("sed", sed_json),
    ]))
}

/// Staleness drift section shared by the trace and report analyzers:
/// per-epoch means with their EWMA, plus threshold warnings.
fn staleness_drift(
    epochs: &[(f64, f64, f64)],
    means: &[f64],
) -> Json {
    let ewma = ewma_series(means);
    let mut warnings = Vec::new();
    for i in 1..means.len() {
        if ewma[i - 1] > 1e-9
            && means[i] > ewma[i - 1] * STALENESS_DRIFT_FACTOR
        {
            warnings.push(Json::str(&format!(
                "staleness drift at epoch {}: mean {:.2} exceeds \
                 EWMA {:.2} by more than {:.0}%",
                epochs[i].0,
                means[i],
                ewma[i - 1],
                (STALENESS_DRIFT_FACTOR - 1.0) * 100.0
            )));
        }
    }
    Json::obj(vec![
        (
            "epochs",
            Json::arr(epochs.iter().zip(&ewma).map(
                |(&(epoch, coverage, mean), &e)| {
                    Json::obj(vec![
                        ("epoch", Json::num(epoch)),
                        ("coverage", Json::num(coverage)),
                        ("mean", Json::num(mean)),
                        ("ewma", Json::num(e)),
                    ])
                },
            )),
        ),
        ("warnings", Json::Arr(warnings)),
    ])
}

/// SED drop-rate drift from the cumulative `epoch_sed` counters: the
/// per-epoch rate is the *delta* drop fraction, EWMA-smoothed, warning
/// when an epoch departs from the running average by more than
/// [`SED_DRIFT_ABS`] (SED draws are Bernoulli with fixed p, so a real
/// departure means the stale-slot population itself shifted).
fn sed_drift(cumulative: &[(f64, f64, f64)]) -> Json {
    let mut rates = Vec::with_capacity(cumulative.len());
    let (mut prev_t, mut prev_d) = (0.0, 0.0);
    for &(_, t, d) in cumulative {
        let (dt, dd) = (t - prev_t, d - prev_d);
        rates.push(if dt > 0.0 { dd / dt } else { 0.0 });
        (prev_t, prev_d) = (t, d);
    }
    let ewma = ewma_series(&rates);
    let mut warnings = Vec::new();
    for i in 1..rates.len() {
        if (rates[i] - ewma[i - 1]).abs() > SED_DRIFT_ABS {
            warnings.push(Json::str(&format!(
                "SED drop-rate drift at epoch {}: {:.3} vs EWMA {:.3}",
                cumulative[i].0, rates[i], ewma[i - 1]
            )));
        }
    }
    Json::obj(vec![
        (
            "epochs",
            Json::arr(cumulative.iter().zip(rates.iter().zip(&ewma)).map(
                |(&(epoch, _, _), (&rate, &e))| {
                    Json::obj(vec![
                        ("epoch", Json::num(epoch)),
                        ("drop_rate", Json::num(rate)),
                        ("ewma", Json::num(e)),
                    ])
                },
            )),
        ),
        ("warnings", Json::Arr(warnings)),
    ])
}

// -- report analysis -----------------------------------------------------

/// Analyze a `gst-run-report` document (v1 or v2) into a
/// `gst-report-analysis/v1` summary: phase shares of step wall-clock,
/// cache hit rates, staleness drift, and — when the report carries them
/// (v2) — the worker/contention sections verbatim.
pub fn analyze_report(doc: &Json) -> Result<Json, String> {
    let schema = check_report_schema(doc)?.to_string();
    let step_ms = num_at(doc, "phases.step.total_ms").unwrap_or(0.0);
    let phases_json = match doc.get("phases").and_then(|p| p.as_obj()) {
        Some(m) => Json::Obj(
            m.iter()
                .map(|(name, p)| {
                    let ms =
                        num_at(p, "total_ms").unwrap_or(0.0);
                    let pct = if step_ms > 0.0 {
                        100.0 * ms / step_ms
                    } else {
                        0.0
                    };
                    (
                        name.clone(),
                        Json::obj(vec![
                            ("total_ms", Json::num(ms)),
                            (
                                "calls",
                                Json::num(
                                    num_at(p, "calls").unwrap_or(0.0),
                                ),
                            ),
                            ("pct_of_step", Json::num(pct)),
                        ]),
                    )
                })
                .collect(),
        ),
        None => Json::Null,
    };
    let epochs: Vec<(f64, f64, f64)> = doc
        .get("staleness")
        .and_then(|s| s.as_arr())
        .map(|arr| {
            arr.iter()
                .map(|e| {
                    (
                        num_at(e, "epoch").unwrap_or(0.0),
                        num_at(e, "coverage").unwrap_or(0.0),
                        num_at(e, "mean").unwrap_or(0.0),
                    )
                })
                .collect()
        })
        .unwrap_or_default();
    let means: Vec<f64> = epochs.iter().map(|&(_, _, m)| m).collect();
    let caches = Json::obj(vec![
        (
            "fill_hit_rate",
            Json::num(num_at(doc, "caches.fill.hit_rate").unwrap_or(0.0)),
        ),
        (
            "param_literal_hit_rate",
            Json::num(
                num_at(doc, "caches.param_literal.hit_rate")
                    .unwrap_or(0.0),
            ),
        ),
    ]);
    Ok(Json::obj(vec![
        ("schema", Json::str("gst-report-analysis/v1")),
        ("source_schema", Json::str(&schema)),
        (
            "steps",
            doc.get("steps").cloned().unwrap_or(Json::Null),
        ),
        ("phases", phases_json),
        ("caches", caches),
        ("staleness", staleness_drift(&epochs, &means)),
        ("sed", doc.get("sed").cloned().unwrap_or(Json::Null)),
        (
            "workers",
            doc.get("workers").cloned().unwrap_or(Json::Null),
        ),
        (
            "contention",
            doc.get("contention").cloned().unwrap_or(Json::Null),
        ),
    ]))
}

// -- report diffing (the perf-regression gate) ---------------------------

/// One compared field: `worse_when_higher` decides the regression
/// direction (time-like fields regress upward, hit rates downward).
struct DiffField {
    name: String,
    base: f64,
    cand: f64,
    worse_when_higher: bool,
    floor: f64,
}

/// Compare two run reports field-by-field. A field regresses when it
/// moved in its worse direction by more than `fail_pct` percent
/// (relative to baseline); fields whose baseline sits under a noise
/// floor are reported but never counted as regressions. Returns the
/// `gst-report-diff/v1` document; `pass` is false iff any field
/// regressed.
pub fn diff_reports(
    base: &Json,
    cand: &Json,
    fail_pct: f64,
) -> Result<Json, String> {
    check_report_schema(base)?;
    check_report_schema(cand)?;
    let mut fields: Vec<DiffField> = Vec::new();
    let mut push = |name: &str, higher_worse: bool, floor: f64| {
        if let (Some(b), Some(c)) = (num_at(base, name), num_at(cand, name))
        {
            fields.push(DiffField {
                name: name.to_string(),
                base: b,
                cand: c,
                worse_when_higher: higher_worse,
                floor,
            });
        }
    };
    for f in ["steady_mean_ms", "p50_ms", "p95_ms"] {
        push(&format!("steps.{f}"), true, MIN_TIME_MS);
    }
    // every phase present in both documents
    if let (Some(bp), Some(cp)) = (
        base.get("phases").and_then(|p| p.as_obj()),
        cand.get("phases").and_then(|p| p.as_obj()),
    ) {
        for name in bp.keys() {
            if cp.contains_key(name) {
                push(
                    &format!("phases.{name}.total_ms"),
                    true,
                    MIN_TIME_MS,
                );
            }
        }
    }
    push("caches.fill.hit_rate", false, MIN_RATE);
    push("caches.param_literal.hit_rate", false, MIN_RATE);
    // v2-only sections: compared only when both reports carry them
    push("workers.imbalance_pct", true, 1.0);
    push("contention.total_wait_ms", true, MIN_TIME_MS);
    push("contention.table_writeback_ms", true, MIN_TIME_MS);
    push("engine.marshalled_bytes", true, 1.0);

    let mut rows = Vec::with_capacity(fields.len());
    let mut regressions = Vec::new();
    for f in &fields {
        let measurable = f.base.abs() >= f.floor;
        let delta_pct = if measurable {
            100.0 * (f.cand - f.base) / f.base
        } else {
            0.0
        };
        let worse = if f.worse_when_higher {
            delta_pct > fail_pct
        } else {
            delta_pct < -fail_pct
        };
        let regression = measurable && worse;
        if regression {
            regressions.push(f.name.clone());
        }
        rows.push(Json::obj(vec![
            ("field", Json::str(&f.name)),
            ("base", Json::num(f.base)),
            ("candidate", Json::num(f.cand)),
            ("delta_pct", Json::num(delta_pct)),
            (
                "worse_direction",
                Json::str(if f.worse_when_higher { "up" } else { "down" }),
            ),
            ("regression", Json::Bool(regression)),
        ]));
    }
    let pass = regressions.is_empty();
    Ok(Json::obj(vec![
        ("schema", Json::str("gst-report-diff/v1")),
        ("fail_on_pct", Json::num(fail_pct)),
        ("fields", Json::Arr(rows)),
        (
            "regressions",
            Json::arr(regressions.iter().map(|r| Json::str(r))),
        ),
        ("pass", Json::Bool(pass)),
    ]))
}

// -- text rendering (the CLI's human-facing view) ------------------------

fn fmt_warnings(out: &mut String, section: &Json) {
    if let Some(warns) = section.get("warnings").and_then(|w| w.as_arr()) {
        for w in warns {
            if let Some(s) = w.as_str() {
                out.push_str(&format!("  warning: {s}\n"));
            }
        }
    }
}

/// Render a `gst-trace-analysis/v1` or `gst-report-analysis/v1`
/// document for the terminal.
pub fn render_analysis(a: &Json) -> String {
    let mut out = String::new();
    let schema = a.get("schema").and_then(|s| s.as_str()).unwrap_or("?");
    out.push_str(&format!("{schema}\n"));
    if let Some(steps) = a.get("steps").filter(|s| s.as_obj().is_some()) {
        let g = |k: &str| num_at(steps, k).unwrap_or(0.0);
        out.push_str(&format!(
            "steps: {}  mean {:.3} ms  p50 {:.3}  p95 {:.3}  max {:.3}\n",
            g("count") as u64,
            g("mean_ms"),
            g("p50_ms"),
            g("p95_ms"),
            g("max_ms")
        ));
    }
    if let Some(phases) = a.get("phases").and_then(|p| p.as_obj()) {
        out.push_str("phase breakdown:\n");
        let mut rows: Vec<_> = phases.iter().collect();
        rows.sort_by(|a, b| {
            num_at(b.1, "total_ms")
                .partial_cmp(&num_at(a.1, "total_ms"))
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        for (name, p) in rows {
            out.push_str(&format!(
                "  {:<14} {:>10.3} ms  {:>5.1}% of step  ({} calls)\n",
                name,
                num_at(p, "total_ms").unwrap_or(0.0),
                num_at(p, "pct_of_step").unwrap_or(0.0),
                num_at(p, "calls").unwrap_or(0.0) as u64
            ));
        }
    }
    if let Some(cp) = a.get("critical_path") {
        let g = |k: &str| num_at(cp, k).unwrap_or(0.0);
        out.push_str(&format!(
            "critical path: sample {:.3} + compute {:.3} + commit {:.3} \
             = {:.3} ms  (stall {:.3})\n",
            g("sample_ms"),
            g("compute_ms"),
            g("commit_ms"),
            g("critical_ms"),
            g("stall_ms")
        ));
    }
    if let Some(w) = a.get("workers").filter(|w| w.as_obj().is_some()) {
        let busy: Vec<String> = w
            .get("busy_ms")
            .and_then(|b| b.as_arr())
            .map(|arr| {
                arr.iter()
                    .map(|v| format!("{:.2}", v.as_f64().unwrap_or(0.0)))
                    .collect()
            })
            .unwrap_or_default();
        out.push_str(&format!(
            "workers: {}  busy [{}] ms  imbalance {:.1}%\n",
            num_at(w, "count").unwrap_or(0.0) as u64,
            busy.join(", "),
            num_at(w, "imbalance_pct").unwrap_or(0.0)
        ));
    }
    if let Some(top) = a.get("top_steps").and_then(|t| t.as_arr()) {
        if !top.is_empty() {
            out.push_str("slowest steps:\n");
            for s in top {
                out.push_str(&format!(
                    "  step {:>5}  {:>9.3} ms  dominant {} ({:.1}%)\n",
                    num_at(s, "step").unwrap_or(0.0) as u64,
                    num_at(s, "dur_ms").unwrap_or(0.0),
                    s.get("dominant_phase")
                        .and_then(|p| p.as_str())
                        .unwrap_or("?"),
                    num_at(s, "dominant_pct").unwrap_or(0.0)
                ));
            }
        }
    }
    if let Some(st) = a.get("staleness").filter(|s| s.as_obj().is_some()) {
        if let Some(arr) = st.get("epochs").and_then(|e| e.as_arr()) {
            if !arr.is_empty() {
                out.push_str("staleness drift (mean / EWMA):\n");
                for e in arr {
                    out.push_str(&format!(
                        "  epoch {:>3}  {:.2} / {:.2}\n",
                        num_at(e, "epoch").unwrap_or(0.0) as u64,
                        num_at(e, "mean").unwrap_or(0.0),
                        num_at(e, "ewma").unwrap_or(0.0)
                    ));
                }
            }
        }
        fmt_warnings(&mut out, st);
    }
    if let Some(sed) = a.get("sed").filter(|s| s.as_obj().is_some()) {
        if let Some(arr) = sed.get("epochs").and_then(|e| e.as_arr()) {
            if !arr.is_empty() {
                out.push_str("SED drop-rate drift (rate / EWMA):\n");
                for e in arr {
                    out.push_str(&format!(
                        "  epoch {:>3}  {:.3} / {:.3}\n",
                        num_at(e, "epoch").unwrap_or(0.0) as u64,
                        num_at(e, "drop_rate").unwrap_or(0.0),
                        num_at(e, "ewma").unwrap_or(0.0)
                    ));
                }
            }
        }
        fmt_warnings(&mut out, sed);
    }
    out
}

/// Render a `gst-report-diff/v1` document for the terminal.
pub fn render_diff(d: &Json) -> String {
    let mut out = String::new();
    let pass = d.get("pass").and_then(|p| p.as_bool()).unwrap_or(false);
    out.push_str(&format!(
        "{:<34} {:>12} {:>12} {:>9}\n",
        "field", "base", "candidate", "delta"
    ));
    if let Some(rows) = d.get("fields").and_then(|f| f.as_arr()) {
        for r in rows {
            let mark = if r
                .get("regression")
                .and_then(|x| x.as_bool())
                .unwrap_or(false)
            {
                "  << REGRESSION"
            } else {
                ""
            };
            out.push_str(&format!(
                "{:<34} {:>12.3} {:>12.3} {:>8.1}%{}\n",
                r.get("field").and_then(|f| f.as_str()).unwrap_or("?"),
                num_at(r, "base").unwrap_or(0.0),
                num_at(r, "candidate").unwrap_or(0.0),
                num_at(r, "delta_pct").unwrap_or(0.0),
                mark
            ));
        }
    }
    out.push_str(&format!(
        "result: {} (fail threshold {:.0}%)\n",
        if pass { "PASS" } else { "FAIL" },
        num_at(d, "fail_on_pct").unwrap_or(0.0)
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_seeds_with_first_value() {
        let e = ewma_series(&[10.0, 10.0, 20.0]);
        assert_eq!(e[0], 10.0);
        assert_eq!(e[1], 10.0);
        assert!((e[2] - (0.3 * 20.0 + 0.7 * 10.0)).abs() < 1e-12);
        assert!(ewma_series(&[]).is_empty());
    }

    fn mini_report(steady: f64, p95: f64, fill_rate: f64) -> Json {
        Json::parse(&format!(
            r#"{{"schema":"gst-run-report/v2",
                "steps":{{"steady_mean_ms":{steady},"p50_ms":{steady},
                          "p95_ms":{p95}}},
                "phases":{{"step":{{"total_ms":10.0,"calls":4}},
                           "fill":{{"total_ms":2.0,"calls":8}}}},
                "caches":{{"fill":{{"hit_rate":{fill_rate}}},
                           "param_literal":{{"hit_rate":0.9}}}},
                "staleness":[]}}"#
        ))
        .unwrap()
    }

    #[test]
    fn identical_reports_pass_the_diff() {
        let r = mini_report(5.0, 8.0, 0.8);
        let d = diff_reports(&r, &r, 20.0).unwrap();
        assert_eq!(d.at("pass").as_bool(), Some(true));
        assert!(d.at("regressions").as_arr().unwrap().is_empty());
    }

    #[test]
    fn slower_candidate_fails_the_diff() {
        let base = mini_report(5.0, 8.0, 0.8);
        let cand = mini_report(6.5, 8.0, 0.8); // +30% steady mean + p50
        let d = diff_reports(&base, &cand, 20.0).unwrap();
        assert_eq!(d.at("pass").as_bool(), Some(false));
        let regs = d.at("regressions").as_arr().unwrap();
        assert!(regs
            .iter()
            .any(|r| r.as_str() == Some("steps.steady_mean_ms")));
    }

    #[test]
    fn hit_rate_regresses_downward() {
        let base = mini_report(5.0, 8.0, 0.8);
        let cand = mini_report(5.0, 8.0, 0.4); // hit rate halved
        let d = diff_reports(&base, &cand, 20.0).unwrap();
        assert_eq!(d.at("pass").as_bool(), Some(false));
        let regs = d.at("regressions").as_arr().unwrap();
        assert!(regs
            .iter()
            .any(|r| r.as_str() == Some("caches.fill.hit_rate")));
        // a *higher* hit rate is an improvement, never a regression
        let better = mini_report(5.0, 8.0, 1.0);
        let d = diff_reports(&base, &better, 20.0).unwrap();
        assert_eq!(d.at("pass").as_bool(), Some(true));
    }

    #[test]
    fn tiny_baselines_never_regress() {
        let base = mini_report(0.001, 0.001, 0.8);
        let cand = mini_report(0.04, 0.04, 0.8); // huge % on noise floor
        let d = diff_reports(&base, &cand, 20.0).unwrap();
        assert_eq!(d.at("pass").as_bool(), Some(true));
    }

    #[test]
    fn diff_rejects_unknown_schemas() {
        let bad = Json::parse(r#"{"schema":"nope/v9"}"#).unwrap();
        let good = mini_report(5.0, 8.0, 0.8);
        assert!(diff_reports(&bad, &good, 20.0).is_err());
        assert!(diff_reports(&good, &bad, 20.0).is_err());
        assert!(check_report_schema(&Json::Null).is_err());
    }

    #[test]
    fn report_reader_accepts_v1_and_v2() {
        let v1 = Json::parse(
            r#"{"schema":"gst-run-report/v1",
                "steps":{"count":2},
                "phases":{"step":{"total_ms":4.0,"calls":2},
                          "fill":{"total_ms":1.0,"calls":4}},
                "caches":{"fill":{"hit_rate":0.5},
                          "param_literal":{"hit_rate":0.9}},
                "staleness":[{"epoch":1,"coverage":0.5,"mean":2.0}]}"#,
        )
        .unwrap();
        let a = analyze_report(&v1).unwrap();
        assert_eq!(
            a.at("source_schema").as_str(),
            Some("gst-run-report/v1")
        );
        // v1 has no worker/contention sections: reader nulls them
        assert_eq!(a.at("workers"), &Json::Null);
        assert_eq!(a.at("contention"), &Json::Null);
        let fill_pct =
            a.at("phases").at("fill").at("pct_of_step").as_f64().unwrap();
        assert!((fill_pct - 25.0).abs() < 1e-9);
        let v2 = mini_report(5.0, 8.0, 0.8);
        assert!(analyze_report(&v2).is_ok());
    }

    #[test]
    fn sed_drift_flags_rate_jumps() {
        // cumulative counters: epoch rates 0.5, 0.5, then 0.9
        let j = sed_drift(&[
            (1.0, 100.0, 50.0),
            (2.0, 200.0, 100.0),
            (3.0, 300.0, 190.0),
        ]);
        let warns = j.at("warnings").as_arr().unwrap();
        assert_eq!(warns.len(), 1);
        assert!(warns[0].as_str().unwrap().contains("epoch 3"));
        let epochs = j.at("epochs").as_arr().unwrap();
        assert!(
            (epochs[2].at("drop_rate").as_f64().unwrap() - 0.9).abs()
                < 1e-12
        );
    }

    #[test]
    fn staleness_drift_flags_mean_jumps() {
        let epochs = [(1.0, 0.5, 2.0), (2.0, 0.8, 2.1), (3.0, 1.0, 9.0)];
        let means = [2.0, 2.1, 9.0];
        let j = staleness_drift(&epochs, &means);
        let warns = j.at("warnings").as_arr().unwrap();
        assert_eq!(warns.len(), 1);
        assert!(warns[0].as_str().unwrap().contains("epoch 3"));
    }

    #[test]
    fn renderers_cover_every_section() {
        let r = mini_report(5.0, 8.0, 0.8);
        let a = analyze_report(&r).unwrap();
        let text = render_analysis(&a);
        assert!(text.contains("phase breakdown"));
        let d = diff_reports(&r, &r, 20.0).unwrap();
        let text = render_diff(&d);
        assert!(text.contains("PASS"));
    }
}
