//! In-repo substrates replacing the usual crates (offline registry carries
//! only the `xla` closure — see DESIGN.md §3): RNG, JSON, CLI, threading,
//! small statistics helpers.

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod threads;
