//! Louvain community detection used as an edge-cut partitioner (Table 6).
//!
//! Standard two-phase Louvain: (1) greedy modularity-gain node moves until
//! convergence, (2) aggregate communities into super-nodes; repeat. The
//! final communities become segments; communities larger than `max_size`
//! are split by the caller's BFS fallback, and tiny communities are merged
//! greedily with their most-connected neighbor community to avoid sliver
//! segments.

use super::SegmentSet;
use crate::graph::Csr;
use crate::util::rng::Pcg64;

pub fn partition(g: &Csr, max_size: usize, rng: &mut Pcg64) -> SegmentSet {
    let n = g.num_nodes();
    if n == 0 {
        return SegmentSet { segments: vec![], edges: None };
    }
    // current community of each original node
    let mut node_comm: Vec<u32> = (0..n as u32).collect();
    // working graph (aggregated); parallel arrays of weighted adjacency
    let mut adj: Vec<Vec<(u32, f64)>> = (0..n)
        .map(|v| g.neighbors(v).iter().map(|&w| (w, 1.0)).collect())
        .collect();
    // self-loop weight of each super-node = edge weight internal to the
    // community it represents (required for correct modularity at level > 0)
    let mut self_w: Vec<f64> = vec![0.0; n];
    let mut members: Vec<Vec<u32>> = (0..n as u32).map(|v| vec![v]).collect();

    for _level in 0..10 {
        let (comm, improved) = one_level(&adj, &self_w, rng);
        if !improved {
            break;
        }
        // relabel communities densely
        let mut dense = vec![u32::MAX; comm.len()];
        let mut next = 0u32;
        for &c in &comm {
            if dense[c as usize] == u32::MAX {
                dense[c as usize] = next;
                next += 1;
            }
        }
        let k = next as usize;
        // update original-node community labels + aggregate members
        let mut new_members: Vec<Vec<u32>> = vec![Vec::new(); k];
        for (v, &c) in comm.iter().enumerate() {
            let d = dense[c as usize];
            new_members[d as usize].append(&mut members[v]);
        }
        for (ci, ms) in new_members.iter().enumerate() {
            for &orig in ms {
                node_comm[orig as usize] = ci as u32;
            }
        }
        members = new_members;
        // aggregate the working graph (intra-community weight becomes the
        // super-node's self loop; each undirected intra edge appears twice
        // in the directed scan, hence the w/2)
        let mut agg: Vec<std::collections::HashMap<u32, f64>> =
            vec![std::collections::HashMap::new(); k];
        let mut new_self = vec![0f64; k];
        for (u, nbrs) in adj.iter().enumerate() {
            let cu = dense[comm[u] as usize];
            new_self[cu as usize] += self_w[u];
            for &(v, w) in nbrs {
                let cv = dense[comm[v as usize] as usize];
                if cu != cv {
                    *agg[cu as usize].entry(cv).or_insert(0.0) += w;
                } else {
                    new_self[cu as usize] += w / 2.0;
                }
            }
        }
        self_w = new_self;
        adj = agg
            .into_iter()
            .map(|m| {
                let mut v: Vec<(u32, f64)> = m.into_iter().collect();
                v.sort_by_key(|&(n, _)| n);
                v
            })
            .collect();
        if adj.len() <= 1 {
            break;
        }
    }

    // communities -> segments; merge slivers (< max_size/8) into their
    // most-connected sibling when the union still fits
    let k = members.len();
    let mut segments: Vec<Vec<u32>> =
        members.into_iter().filter(|m| !m.is_empty()).collect();
    merge_slivers(g, &mut segments, max_size, k);
    for s in &mut segments {
        s.sort_unstable();
    }
    let _ = node_comm;
    let mut set = SegmentSet { segments, edges: None };
    // communities can exceed max_size on graphs with one dominant cluster;
    // split them here so direct callers get the contract too
    super::enforce_max_size(g, &mut set, max_size);
    set
}

/// One Louvain level: greedy modularity moves. Returns (community of each
/// node, whether anything moved).
fn one_level(
    adj: &[Vec<(u32, f64)>],
    self_w: &[f64],
    rng: &mut Pcg64,
) -> (Vec<u32>, bool) {
    let n = adj.len();
    // k_v includes self loops twice (modularity convention); m2 = Σ k_v
    let deg: Vec<f64> = adj
        .iter()
        .zip(self_w)
        .map(|(nb, &sw)| {
            nb.iter().map(|&(_, w)| w).sum::<f64>() + 2.0 * sw
        })
        .collect();
    let m2: f64 = deg.iter().sum::<f64>().max(1.0);
    let mut comm: Vec<u32> = (0..n as u32).collect();
    let mut comm_deg = deg.clone(); // total degree per community
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut improved = false;
    for _pass in 0..8 {
        let mut moves = 0usize;
        for &v in &order {
            let v = v as usize;
            let cv = comm[v];
            // weights to neighboring communities
            let mut conn: Vec<(u32, f64)> = Vec::new();
            for &(u, w) in &adj[v] {
                let cu = comm[u as usize];
                match conn.iter_mut().find(|(c, _)| *c == cu) {
                    Some((_, cw)) => *cw += w,
                    None => conn.push((cu, w)),
                }
            }
            // remove v from its community, then compare the standard
            // modularity score  w(v,c) - deg(v)·Σtot(c) / 2m  across all
            // candidate communities (including staying put)
            comm_deg[cv as usize] -= deg[v];
            let score = |c: u32, w: f64| -> f64 {
                w - deg[v] * comm_deg[c as usize] / m2
            };
            let own = conn
                .iter()
                .find(|(c, _)| *c == cv)
                .map(|&(_, w)| w)
                .unwrap_or(0.0);
            let mut best = (cv, score(cv, own));
            for &(c, w) in &conn {
                if c != cv && score(c, w) > best.1 + 1e-12 {
                    best = (c, score(c, w));
                }
            }
            comm_deg[best.0 as usize] += deg[v];
            if best.0 != cv {
                comm[v] = best.0;
                moves += 1;
                improved = true;
            }
        }
        if moves == 0 {
            break;
        }
    }
    (comm, improved)
}

fn merge_slivers(
    g: &Csr,
    segments: &mut Vec<Vec<u32>>,
    max_size: usize,
    _k: usize,
) {
    let sliver = (max_size / 8).max(2);
    loop {
        // locate the smallest sliver
        let Some(si) = segments
            .iter()
            .enumerate()
            .filter(|(_, s)| s.len() < sliver)
            .min_by_key(|(_, s)| s.len())
            .map(|(i, _)| i)
        else {
            break;
        };
        if segments.len() <= 1 {
            break;
        }
        // most-connected other segment that still fits
        let mut part = vec![u32::MAX; g.num_nodes()];
        for (i, s) in segments.iter().enumerate() {
            for &v in s {
                part[v as usize] = i as u32;
            }
        }
        let mut conn = vec![0usize; segments.len()];
        for &v in &segments[si] {
            for &u in g.neighbors(v as usize) {
                let p = part[u as usize] as usize;
                if p != si {
                    conn[p] += 1;
                }
            }
        }
        let target = (0..segments.len())
            .filter(|&j| {
                j != si
                    && segments[j].len() + segments[si].len() <= max_size
            })
            .max_by_key(|&j| (conn[j], std::cmp::Reverse(segments[j].len())));
        match target {
            Some(j) if conn[j] > 0 || segments[si].len() < sliver => {
                let mut moved = std::mem::take(&mut segments[si]);
                segments[j].append(&mut moved);
                segments.remove(si);
            }
            _ => break,
        }
    }
    segments.retain(|s| !s.is_empty());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    /// Four 25-node cliques in a ring (classic community structure).
    fn clique_ring() -> Csr {
        let mut b = GraphBuilder::new(100, 0);
        for c in 0..4 {
            let off = c * 25;
            for i in 0..25 {
                for j in i + 1..25 {
                    b.add_edge(off + i, off + j);
                }
            }
        }
        for c in 0..4 {
            b.add_edge(c * 25, ((c + 1) % 4) * 25);
        }
        b.build()
    }

    #[test]
    fn finds_the_cliques() {
        let g = clique_ring();
        let mut rng = Pcg64::new(0, 0);
        let set = partition(&g, 30, &mut rng);
        set.validate(&g, 30).unwrap();
        assert_eq!(set.segments.len(), 4, "{:?}",
                   set.segments.iter().map(|s| s.len()).collect::<Vec<_>>());
        // cut must be exactly the 4 ring edges
        assert_eq!(set.cut_cost(&g), 4);
    }

    #[test]
    fn merges_slivers() {
        // a path graph fragments into many tiny communities; after merging
        // no segment should be tiny unless the graph itself is
        let mut b = GraphBuilder::new(64, 0);
        for i in 0..63 {
            b.add_edge(i, i + 1);
        }
        let g = b.build();
        let mut rng = Pcg64::new(1, 1);
        let set = partition(&g, 32, &mut rng);
        set.validate(&g, 32).unwrap();
        assert!(
            set.segments.iter().all(|s| s.len() >= 4),
            "sliver survived: {:?}",
            set.segments.iter().map(|s| s.len()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn empty_graph() {
        let g = GraphBuilder::new(0, 0).build();
        let mut rng = Pcg64::new(0, 0);
        assert!(partition(&g, 8, &mut rng).segments.is_empty());
    }
}
