//! Graph/dataset statistics — regenerates the paper's Table 4.

use super::Csr;

/// Summary statistics for a collection of graphs.
#[derive(Clone, Debug, Default)]
pub struct GraphStats {
    pub count: usize,
    pub avg_nodes: f64,
    pub min_nodes: usize,
    pub max_nodes: usize,
    pub avg_edges: f64,
    pub min_edges: usize,
    pub max_edges: usize,
}

impl GraphStats {
    pub fn over(graphs: &[Csr]) -> GraphStats {
        if graphs.is_empty() {
            return GraphStats::default();
        }
        let nodes: Vec<usize> = graphs.iter().map(|g| g.num_nodes()).collect();
        let edges: Vec<usize> = graphs.iter().map(|g| g.num_edges()).collect();
        GraphStats {
            count: graphs.len(),
            avg_nodes: nodes.iter().sum::<usize>() as f64 / graphs.len() as f64,
            min_nodes: *nodes.iter().min().unwrap(),
            max_nodes: *nodes.iter().max().unwrap(),
            avg_edges: edges.iter().sum::<usize>() as f64 / graphs.len() as f64,
            min_edges: *edges.iter().min().unwrap(),
            max_edges: *edges.iter().max().unwrap(),
        }
    }

    /// One row in the Table 4 layout.
    pub fn row(&self, name: &str) -> String {
        format!(
            "{name:<14} {:>10.0} {:>10} {:>10} {:>10.0} {:>10} {:>10}",
            self.avg_nodes,
            self.min_nodes,
            self.max_nodes,
            self.avg_edges,
            self.min_edges,
            self.max_edges
        )
    }

    pub fn header() -> String {
        format!(
            "{:<14} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10}",
            "", "avg#nodes", "min#nodes", "max#nodes", "avg#edges",
            "min#edges", "max#edges"
        )
    }
}

/// Degree histogram over one graph (log2 buckets) — input to the LDP-style
/// node features and handy for generator sanity checks.
pub fn degree_log2_histogram(g: &Csr, buckets: usize) -> Vec<usize> {
    let mut hist = vec![0usize; buckets];
    for v in 0..g.num_nodes() {
        let d = g.degree(v);
        let b = if d == 0 {
            0
        } else {
            ((d as f64).log2().floor() as usize + 1).min(buckets - 1)
        };
        hist[b] += 1;
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn path(n: usize) -> Csr {
        let mut b = GraphBuilder::new(n, 0);
        for i in 0..n - 1 {
            b.add_edge(i, i + 1);
        }
        b.build()
    }

    #[test]
    fn stats_over_collection() {
        let gs = vec![path(3), path(5), path(10)];
        let s = GraphStats::over(&gs);
        assert_eq!(s.count, 3);
        assert_eq!(s.min_nodes, 3);
        assert_eq!(s.max_nodes, 10);
        assert!((s.avg_nodes - 6.0).abs() < 1e-9);
        assert_eq!(s.min_edges, 2);
        assert_eq!(s.max_edges, 9);
    }

    #[test]
    fn stats_empty() {
        assert_eq!(GraphStats::over(&[]).count, 0);
    }

    #[test]
    fn degree_histogram() {
        let g = path(5); // degrees 1,2,2,2,1
        let h = degree_log2_histogram(&g, 4);
        assert_eq!(h[1], 2); // degree 1 -> bucket 1
        assert_eq!(h[2], 3); // degree 2 -> bucket 2
        assert_eq!(h[0], 0);
    }
}
