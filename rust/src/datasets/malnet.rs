//! Synthetic MalNet: 5-class function-call-graph classification.
//!
//! Real MalNet graphs are Android call graphs whose malware family shows in
//! *global* structure — which is exactly why the paper argues a fixed-size
//! subgraph cannot classify them. The generator reproduces that property:
//! each class mixes the same building blocks (preferential-attachment
//! modules wired sparsely, like code packages) in class-specific
//! proportions, so the signal is a whole-graph motif distribution, not any
//! single local pattern:
//!
//! | class | flavour                | motif bias                        |
//! |-------|------------------------|-----------------------------------|
//! | 0     | benign-utility         | long call chains                  |
//! | 1     | spyware-like           | star fan-outs (dispatcher hubs)   |
//! | 2     | packer-like            | dense cliques (obfuscated blobs)  |
//! | 3     | worm-like              | long cycles                       |
//! | 4     | trojan-like            | 2-level trees + cross edges       |
//!
//! Sizes are ~16× scaled down from the paper (DESIGN.md §2): `tiny` avg
//! ≈ 300 nodes (paper 1.4k), `large` avg ≈ 3k, max ≈ 20k (paper 47.8k/541k).

use super::features::{with_ldp_features, LDP_DIM};
use crate::graph::{Csr, GraphBuilder};
use crate::util::rng::Pcg64;

pub const NUM_CLASSES: usize = 5;

/// Which synthetic MalNet split to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MalnetSplit {
    Tiny,
    Large,
}

impl MalnetSplit {
    /// (min_nodes, max_nodes, lognormal mu) — chosen so tiny averages ≈300
    /// and large ≈3k with a heavy right tail like the paper's Table 4.
    fn size_params(self) -> (usize, usize, f64) {
        match self {
            MalnetSplit::Tiny => (60, 1_200, 5.5),
            MalnetSplit::Large => (600, 20_000, 7.8),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MalnetSplit::Tiny => "malnet-tiny",
            MalnetSplit::Large => "malnet-large",
        }
    }
}

/// A generated dataset with train/val/test splits (70/10/20, stratified).
pub struct MalnetDataset {
    pub graphs: Vec<Csr>,
    pub labels: Vec<u8>,
    pub train: Vec<usize>,
    pub val: Vec<usize>,
    pub test: Vec<usize>,
    pub split: MalnetSplit,
}

impl MalnetDataset {
    /// Generate `count` graphs (balanced over the 5 classes).
    pub fn generate(split: MalnetSplit, count: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 0x3a17);
        let mut graphs = Vec::with_capacity(count);
        let mut labels = Vec::with_capacity(count);
        for i in 0..count {
            let class = (i % NUM_CLASSES) as u8;
            graphs.push(generate_graph(split, class, &mut rng));
            labels.push(class);
        }
        // stratified split: within each class 70/10/20
        let (mut train, mut val, mut test) = (vec![], vec![], vec![]);
        for c in 0..NUM_CLASSES {
            let mut idx: Vec<usize> =
                (0..count).filter(|&i| labels[i] as usize == c).collect();
            rng.shuffle(&mut idx);
            let n = idx.len();
            let (ntr, nva) = (n * 7 / 10, n / 10);
            train.extend_from_slice(&idx[..ntr]);
            val.extend_from_slice(&idx[ntr..ntr + nva]);
            test.extend_from_slice(&idx[ntr + nva..]);
        }
        rng.shuffle(&mut train);
        MalnetDataset { graphs, labels, train, val, test, split }
    }

    pub fn feat_dim(&self) -> usize {
        LDP_DIM
    }
}

/// One synthetic call graph of the given class.
pub fn generate_graph(split: MalnetSplit, class: u8, rng: &mut Pcg64) -> Csr {
    let (min_n, max_n, mu) = split.size_params();
    // lognormal node count, clamped — heavy right tail like real MalNet
    let n = ((mu + 0.75 * rng.normal()).exp() as usize).clamp(min_n, max_n);
    let topo = build_topology(n, class, rng);
    with_ldp_features(&topo)
}

/// Class-conditional motif mixture: (chain, star, clique, cycle, tree)
/// fractions of module budget.
fn motif_mix(class: u8) -> [f64; 5] {
    match class {
        0 => [0.76, 0.06, 0.03, 0.06, 0.09],
        1 => [0.06, 0.76, 0.03, 0.06, 0.09],
        2 => [0.03, 0.06, 0.76, 0.06, 0.09],
        3 => [0.06, 0.06, 0.03, 0.76, 0.09],
        _ => [0.06, 0.09, 0.06, 0.06, 0.73],
    }
}

fn build_topology(n: usize, class: u8, rng: &mut Pcg64) -> Csr {
    let mut b = GraphBuilder::new(n, 0);
    // Module structure: split nodes into packages of 30-120 nodes. Each
    // module gets a preferential-attachment backbone plus class motifs.
    let mut module_starts = vec![0usize];
    let mut cursor = 0usize;
    while cursor < n {
        let sz = 30 + rng.below(91);
        cursor = (cursor + sz).min(n);
        module_starts.push(cursor);
    }
    let mix = motif_mix(class);
    for w in module_starts.windows(2) {
        let (lo, hi) = (w[0], w[1]);
        if hi - lo >= 2 {
            build_module(&mut b, lo, hi, &mix, rng);
        }
    }
    // sparse inter-module call edges (makes partitioning non-trivial but
    // locality-preserving partitioners effective — the Table 6 setting)
    let nmods = module_starts.len() - 1;
    if nmods > 1 {
        let inter = (n / 20).max(nmods - 1);
        for k in 0..inter {
            let (ma, mb) = if k < nmods - 1 {
                (k, k + 1) // ensure connectivity of consecutive modules
            } else {
                (rng.below(nmods), rng.below(nmods))
            };
            let a = module_starts[ma]
                + rng.below(module_starts[ma + 1] - module_starts[ma]);
            let bn = module_starts[mb]
                + rng.below(module_starts[mb + 1] - module_starts[mb]);
            if a != bn {
                b.add_edge(a, bn);
            }
        }
    }
    b.build()
}

fn build_module(
    b: &mut GraphBuilder,
    lo: usize,
    hi: usize,
    mix: &[f64; 5],
    rng: &mut Pcg64,
) {
    let size = hi - lo;
    // preferential-attachment backbone over the module
    let mut targets: Vec<usize> = vec![lo, lo + 1];
    b.add_edge(lo, lo + 1);
    for v in lo + 2..hi {
        let m = 1 + rng.below(2);
        for _ in 0..m {
            let t = targets[rng.below(targets.len())];
            if t != v {
                b.add_edge(v, t);
                targets.push(t);
            }
        }
        targets.push(v);
    }
    // motif injection proportional to the class mix
    let budget = (size / 4).max(1);
    for _ in 0..budget {
        let r = rng.f64();
        let motif = if r < mix[0] {
            0
        } else if r < mix[0] + mix[1] {
            1
        } else if r < mix[0] + mix[1] + mix[2] {
            2
        } else if r < mix[0] + mix[1] + mix[2] + mix[3] {
            3
        } else {
            4
        };
        inject_motif(b, lo, hi, motif, rng);
    }
}

fn inject_motif(
    b: &mut GraphBuilder,
    lo: usize,
    hi: usize,
    motif: usize,
    rng: &mut Pcg64,
) {
    let size = hi - lo;
    let pick = |rng: &mut Pcg64| lo + rng.below(size);
    match motif {
        0 => {
            // chain of 4-10 random nodes
            let len = 4 + rng.below(7);
            let mut prev = pick(rng);
            for _ in 0..len {
                let next = pick(rng);
                if next != prev {
                    b.add_edge(prev, next);
                    prev = next;
                }
            }
        }
        1 => {
            // star: hub plus 5-12 leaves
            let hub = pick(rng);
            for _ in 0..5 + rng.below(8) {
                let leaf = pick(rng);
                if leaf != hub {
                    b.add_edge(hub, leaf);
                }
            }
        }
        2 => {
            // clique of 4-6 nodes
            let k = 4 + rng.below(3);
            let nodes: Vec<usize> = (0..k).map(|_| pick(rng)).collect();
            for i in 0..k {
                for j in i + 1..k {
                    if nodes[i] != nodes[j] {
                        b.add_edge(nodes[i], nodes[j]);
                    }
                }
            }
        }
        3 => {
            // cycle of 5-12 nodes
            let k = 5 + rng.below(8);
            let nodes: Vec<usize> = (0..k).map(|_| pick(rng)).collect();
            for i in 0..k {
                let (u, v) = (nodes[i], nodes[(i + 1) % k]);
                if u != v {
                    b.add_edge(u, v);
                }
            }
        }
        _ => {
            // 2-level tree: root -> 3 mids -> 3 leaves each, plus a cross
            let root = pick(rng);
            for _ in 0..3 {
                let mid = pick(rng);
                if mid == root {
                    continue;
                }
                b.add_edge(root, mid);
                for _ in 0..3 {
                    let leaf = pick(rng);
                    if leaf != mid {
                        b.add_edge(mid, leaf);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphStats;

    #[test]
    fn generation_is_deterministic() {
        let a = MalnetDataset::generate(MalnetSplit::Tiny, 10, 7);
        let b = MalnetDataset::generate(MalnetSplit::Tiny, 10, 7);
        assert_eq!(a.labels, b.labels);
        for (x, y) in a.graphs.iter().zip(&b.graphs) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn labels_balanced_and_splits_disjoint() {
        let d = MalnetDataset::generate(MalnetSplit::Tiny, 50, 1);
        for c in 0..NUM_CLASSES as u8 {
            assert_eq!(d.labels.iter().filter(|&&l| l == c).count(), 10);
        }
        let mut all: Vec<usize> = d
            .train
            .iter()
            .chain(&d.val)
            .chain(&d.test)
            .copied()
            .collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 50);
        assert_eq!(d.train.len(), 35);
        assert_eq!(d.val.len(), 5);
        assert_eq!(d.test.len(), 10);
    }

    #[test]
    fn tiny_sizes_in_range() {
        let d = MalnetDataset::generate(MalnetSplit::Tiny, 20, 3);
        let s = GraphStats::over(&d.graphs);
        assert!(s.min_nodes >= 60);
        assert!(s.max_nodes <= 1_200);
        assert!(s.avg_nodes > 100.0, "avg={}", s.avg_nodes);
    }

    #[test]
    fn graphs_are_mostly_connected() {
        let d = MalnetDataset::generate(MalnetSplit::Tiny, 10, 5);
        for g in &d.graphs {
            let comp = g.components();
            let ncomp = *comp.iter().max().unwrap() as usize + 1;
            // modules are chained, so the graph should be near-connected
            assert!(ncomp <= 3, "ncomp={ncomp} n={}", g.num_nodes());
        }
    }

    #[test]
    fn classes_have_distinct_structure() {
        // clique-heavy class 2 must have higher mean clustering proxy than
        // chain-heavy class 0 (feature 14 of the LDP profile)
        let mut rng = Pcg64::new(11, 0);
        let mean_clust = |class: u8, rng: &mut Pcg64| {
            let g = generate_graph(MalnetSplit::Tiny, class, rng);
            let s: f32 =
                (0..g.num_nodes()).map(|v| g.feat(v)[14]).sum::<f32>();
            s / g.num_nodes() as f32
        };
        let c0: f32 =
            (0..5).map(|_| mean_clust(0, &mut rng)).sum::<f32>() / 5.0;
        let c2: f32 =
            (0..5).map(|_| mean_clust(2, &mut rng)).sum::<f32>() / 5.0;
        assert!(c2 > c0, "clique class {c2} <= chain class {c0}");
    }

    #[test]
    fn features_installed() {
        let d = MalnetDataset::generate(MalnetSplit::Tiny, 5, 2);
        assert_eq!(d.feat_dim(), LDP_DIM);
        for g in &d.graphs {
            assert_eq!(g.feat_dim, LDP_DIM);
        }
    }
}
