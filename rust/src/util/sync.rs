//! Contention-aware lock wrappers (`TimedMutex`, `TimedRwLock`).
//!
//! The GST compute phase shares a handful of locks across worker
//! threads — the engine's executable/parameter-literal caches, its call
//! counters, and the fill-block cache. These wrappers make that
//! contention *measurable*: every acquisition first tries the lock
//! without blocking (the steady-state fast path costs two relaxed
//! atomic increments), and only a failed try falls back to a timed
//! blocking acquire, accumulating the wait into [`LockStats`].
//!
//! Telemetry-only by construction: the wrappers never change locking
//! semantics (same poisoning behavior, same guards), so wrapping a lock
//! can never change trained parameters — only explain where the wall
//! clock went.
//!
//! Per-phase attribution: blocked waits are additionally charged to the
//! thread's current *phase slot* — a thread-local index the
//! observability layer sets when a phase span opens (`obs::Span` maps
//! its `Phase` to a slot here; this module stays phase-agnostic so the
//! dependency keeps pointing obs → util). Waits outside any span land
//! in the [`UNTAGGED_SLOT`].

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{
    Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
    TryLockError,
};
use std::time::Instant;

use crate::util::json::Json;

/// Number of phase slots blocked waits are attributed to: the 8 fixed
/// `obs::Phase` variants plus one untagged slot.
pub const PHASE_SLOTS: usize = 9;
/// Slot charged when a thread blocks outside any phase span (or with
/// the recorder disabled).
pub const UNTAGGED_SLOT: usize = PHASE_SLOTS - 1;

thread_local! {
    /// Phase slot this thread's blocked lock waits are charged to.
    static CUR_PHASE: Cell<usize> = Cell::new(UNTAGGED_SLOT);
}

/// Tag this thread's subsequent blocked lock waits with `slot`
/// (clamped into range); returns the previous slot so callers can nest
/// and restore — `obs::Span` calls this on open and drop.
pub fn swap_wait_phase(slot: usize) -> usize {
    CUR_PHASE.with(|c| {
        let prev = c.get();
        c.set(slot.min(UNTAGGED_SLOT));
        prev
    })
}

/// The slot currently charged on this thread (test hook).
pub fn current_wait_phase() -> usize {
    CUR_PHASE.with(|c| c.get())
}

/// Cumulative contention counters of one lock.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LockStats {
    /// Total time spent blocked waiting for the lock, in ns.
    pub wait_ns: u64,
    /// Total acquisitions (fast path + contended).
    pub acquisitions: u64,
    /// Acquisitions that found the lock held and had to block.
    pub contended: u64,
    /// `wait_ns` split by the waiter's phase slot at block time
    /// (`obs::Phase` order, slot [`UNTAGGED_SLOT`] = outside any span);
    /// the slots always sum to `wait_ns`.
    pub wait_ns_by: [u64; PHASE_SLOTS],
}

impl LockStats {
    pub fn wait_ms(&self) -> f64 {
        self.wait_ns as f64 / 1e6
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("wait_ms", Json::num(self.wait_ms())),
            ("acquisitions", Json::num(self.acquisitions as f64)),
            ("contended", Json::num(self.contended as f64)),
        ])
    }
}

/// Shared counter cell (one per wrapped lock).
#[derive(Default)]
struct Counters {
    wait_ns: AtomicU64,
    acquisitions: AtomicU64,
    contended: AtomicU64,
    wait_ns_by: [AtomicU64; PHASE_SLOTS],
}

impl Counters {
    fn snapshot(&self) -> LockStats {
        LockStats {
            wait_ns: self.wait_ns.load(Ordering::Relaxed),
            acquisitions: self.acquisitions.load(Ordering::Relaxed),
            contended: self.contended.load(Ordering::Relaxed),
            wait_ns_by: std::array::from_fn(|i| {
                self.wait_ns_by[i].load(Ordering::Relaxed)
            }),
        }
    }

    fn blocked(&self, waited: Instant) {
        let ns = waited.elapsed().as_nanos() as u64;
        self.contended.fetch_add(1, Ordering::Relaxed);
        self.wait_ns.fetch_add(ns, Ordering::Relaxed);
        self.wait_ns_by[current_wait_phase()]
            .fetch_add(ns, Ordering::Relaxed);
    }
}

/// `Mutex` that counts acquisitions and accumulates blocked wait time.
pub struct TimedMutex<T> {
    inner: Mutex<T>,
    counters: Counters,
}

impl<T> TimedMutex<T> {
    pub fn new(value: T) -> TimedMutex<T> {
        TimedMutex { inner: Mutex::new(value), counters: Counters::default() }
    }

    /// Acquire the lock; panics on poison (matching the bare
    /// `.lock().expect(...)` idiom this wrapper replaces).
    pub fn lock(&self) -> MutexGuard<'_, T> {
        self.counters.acquisitions.fetch_add(1, Ordering::Relaxed);
        match self.inner.try_lock() {
            Ok(g) => g,
            Err(TryLockError::WouldBlock) => {
                let t0 = Instant::now();
                let g = self.inner.lock().expect("timed mutex poisoned");
                self.counters.blocked(t0);
                g
            }
            Err(TryLockError::Poisoned(_)) => {
                panic!("timed mutex poisoned")
            }
        }
    }

    /// Cumulative contention counters since construction.
    pub fn stats(&self) -> LockStats {
        self.counters.snapshot()
    }
}

/// `RwLock` counterpart: reads and writes share one counter set (the
/// interesting signal is total blocked time, not the read/write split).
pub struct TimedRwLock<T> {
    inner: RwLock<T>,
    counters: Counters,
}

impl<T> TimedRwLock<T> {
    pub fn new(value: T) -> TimedRwLock<T> {
        TimedRwLock {
            inner: RwLock::new(value),
            counters: Counters::default(),
        }
    }

    pub fn read(&self) -> RwLockReadGuard<'_, T> {
        self.counters.acquisitions.fetch_add(1, Ordering::Relaxed);
        match self.inner.try_read() {
            Ok(g) => g,
            Err(TryLockError::WouldBlock) => {
                let t0 = Instant::now();
                let g = self.inner.read().expect("timed rwlock poisoned");
                self.counters.blocked(t0);
                g
            }
            Err(TryLockError::Poisoned(_)) => {
                panic!("timed rwlock poisoned")
            }
        }
    }

    pub fn write(&self) -> RwLockWriteGuard<'_, T> {
        self.counters.acquisitions.fetch_add(1, Ordering::Relaxed);
        match self.inner.try_write() {
            Ok(g) => g,
            Err(TryLockError::WouldBlock) => {
                let t0 = Instant::now();
                let g = self.inner.write().expect("timed rwlock poisoned");
                self.counters.blocked(t0);
                g
            }
            Err(TryLockError::Poisoned(_)) => {
                panic!("timed rwlock poisoned")
            }
        }
    }

    pub fn stats(&self) -> LockStats {
        self.counters.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uncontended_lock_counts_without_waiting() {
        let m = TimedMutex::new(0usize);
        for _ in 0..3 {
            *m.lock() += 1;
        }
        let s = m.stats();
        assert_eq!(*m.lock(), 3);
        assert_eq!(s.acquisitions, 3);
        assert_eq!(s.contended, 0);
        assert_eq!(s.wait_ns, 0);
    }

    #[test]
    fn contended_lock_records_wait_time() {
        let m = TimedMutex::new(());
        std::thread::scope(|scope| {
            let g = m.lock();
            let t = scope.spawn(|| {
                // blocks until the holder drops its guard
                drop(m.lock());
            });
            // acquisitions increments before the try, so once it reads 2
            // the spawned thread is at (or past) its failing try_lock
            while m.stats().acquisitions < 2 {
                std::thread::yield_now();
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
            drop(g);
            t.join().unwrap();
        });
        let s = m.stats();
        assert_eq!(s.acquisitions, 2);
        assert_eq!(s.contended, 1);
        assert!(s.wait_ns > 0, "blocked acquire recorded no wait");
        assert!(s.wait_ms() > 0.0);
    }

    #[test]
    fn rwlock_counts_reads_and_writes() {
        let l = TimedRwLock::new(5usize);
        assert_eq!(*l.read(), 5);
        *l.write() = 7;
        assert_eq!(*l.read(), 7);
        let s = l.stats();
        assert_eq!(s.acquisitions, 3);
        assert_eq!(s.contended, 0);
    }

    #[test]
    fn rwlock_write_blocked_by_reader_is_contended() {
        let l = TimedRwLock::new(0usize);
        std::thread::scope(|scope| {
            let g = l.read();
            let t = scope.spawn(|| {
                *l.write() = 1;
            });
            while l.stats().acquisitions < 2 {
                std::thread::yield_now();
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
            drop(g);
            t.join().unwrap();
        });
        let s = l.stats();
        assert_eq!(s.acquisitions, 2);
        assert_eq!(s.contended, 1);
        assert!(s.wait_ns > 0);
        assert_eq!(*l.read(), 1);
    }

    #[test]
    fn stats_serialize_to_json() {
        let s = LockStats {
            wait_ns: 2_000_000,
            acquisitions: 9,
            contended: 1,
            ..LockStats::default()
        };
        let j = s.to_json();
        assert_eq!(j.at("wait_ms").as_f64(), Some(2.0));
        assert_eq!(j.at("acquisitions").as_f64(), Some(9.0));
        assert_eq!(j.at("contended").as_f64(), Some(1.0));
    }

    #[test]
    fn wait_phase_tag_swaps_and_restores() {
        assert_eq!(current_wait_phase(), UNTAGGED_SLOT);
        let prev = swap_wait_phase(3);
        assert_eq!(prev, UNTAGGED_SLOT);
        assert_eq!(current_wait_phase(), 3);
        // out-of-range slots clamp into the untagged slot
        assert_eq!(swap_wait_phase(99), 3);
        assert_eq!(current_wait_phase(), UNTAGGED_SLOT);
        swap_wait_phase(prev);
        assert_eq!(current_wait_phase(), UNTAGGED_SLOT);
    }

    #[test]
    fn blocked_wait_is_charged_to_the_waiters_phase_slot() {
        let m = TimedMutex::new(());
        std::thread::scope(|scope| {
            let g = m.lock();
            let t = scope.spawn(|| {
                let prev = swap_wait_phase(4);
                drop(m.lock()); // blocks until the holder releases
                swap_wait_phase(prev);
            });
            while m.stats().acquisitions < 2 {
                std::thread::yield_now();
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
            drop(g);
            t.join().unwrap();
        });
        let s = m.stats();
        assert_eq!(s.contended, 1);
        assert!(s.wait_ns_by[4] > 0, "phase slot 4 recorded no wait");
        for (slot, &ns) in s.wait_ns_by.iter().enumerate() {
            if slot != 4 {
                assert_eq!(ns, 0, "unexpected wait in slot {slot}");
            }
        }
        // the split always reconciles with the total
        assert_eq!(s.wait_ns_by.iter().sum::<u64>(), s.wait_ns);
    }

    #[test]
    fn untagged_waits_land_in_the_untagged_slot() {
        let l = TimedRwLock::new(0usize);
        std::thread::scope(|scope| {
            let g = l.read();
            let t = scope.spawn(|| {
                *l.write() = 1; // no phase tag on this thread
            });
            while l.stats().acquisitions < 2 {
                std::thread::yield_now();
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
            drop(g);
            t.join().unwrap();
        });
        let s = l.stats();
        assert!(s.wait_ns_by[UNTAGGED_SLOT] > 0);
        assert_eq!(s.wait_ns_by.iter().sum::<u64>(), s.wait_ns);
    }
}
