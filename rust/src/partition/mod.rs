//! Graph partitioners: turn one large graph into bounded-size segments.
//!
//! The paper's Table 6 ablates six algorithms; all are implemented here
//! from scratch (METIS itself is not redistributable in this environment —
//! DESIGN.md §2):
//!
//! | family     | algorithm            | module         |
//! |------------|----------------------|----------------|
//! | Edge-Cut   | Random               | [`edge_cut`]   |
//! | Edge-Cut   | Louvain              | [`louvain`]    |
//! | Edge-Cut   | METIS-like multilevel| [`metis_like`] |
//! | Edge-Cut   | BFS (extra baseline) | [`edge_cut`]   |
//! | Vertex-Cut | Random               | [`vertex_cut`] |
//! | Vertex-Cut | DBH                  | [`vertex_cut`] |
//! | Vertex-Cut | NE                   | [`vertex_cut`] |
//!
//! Contract (enforced by [`SegmentSet::validate`] and the property tests):
//! every node appears in ≥ 1 segment (exactly 1 for edge-cut), and every
//! segment has ≤ `max_size` nodes — the paper's m_GST bound that gives GST
//! its constant memory footprint.

pub mod edge_cut;
pub mod louvain;
pub mod metis_like;
pub mod vertex_cut;

use crate::graph::Csr;
use crate::util::rng::Pcg64;

/// The output of any partitioner.
#[derive(Clone, Debug)]
pub struct SegmentSet {
    /// Node ids (into the parent graph) per segment, each sorted.
    pub segments: Vec<Vec<u32>>,
    /// For vertex-cut partitioners: the explicit edge set per segment
    /// (edge-cut segments use the induced subgraph instead).
    pub edges: Option<Vec<Vec<(u32, u32)>>>,
}

/// Which algorithm to run — string form used by CLI/configs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    EdgeCutRandom,
    EdgeCutBfs,
    Louvain,
    MetisLike,
    VertexCutRandom,
    VertexCutDbh,
    VertexCutNe,
}

impl Algorithm {
    pub fn parse(s: &str) -> Option<Algorithm> {
        Some(match s {
            "random" | "edge-random" => Algorithm::EdgeCutRandom,
            "bfs" => Algorithm::EdgeCutBfs,
            "louvain" => Algorithm::Louvain,
            "metis" | "metis-like" => Algorithm::MetisLike,
            "vc-random" => Algorithm::VertexCutRandom,
            "dbh" => Algorithm::VertexCutDbh,
            "ne" => Algorithm::VertexCutNe,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Algorithm::EdgeCutRandom => "edge-cut/random",
            Algorithm::EdgeCutBfs => "edge-cut/bfs",
            Algorithm::Louvain => "edge-cut/louvain",
            Algorithm::MetisLike => "edge-cut/metis-like",
            Algorithm::VertexCutRandom => "vertex-cut/random",
            Algorithm::VertexCutDbh => "vertex-cut/dbh",
            Algorithm::VertexCutNe => "vertex-cut/ne",
        }
    }

    pub fn all() -> [Algorithm; 7] {
        [
            Algorithm::EdgeCutRandom,
            Algorithm::EdgeCutBfs,
            Algorithm::Louvain,
            Algorithm::MetisLike,
            Algorithm::VertexCutRandom,
            Algorithm::VertexCutDbh,
            Algorithm::VertexCutNe,
        ]
    }

    /// Partition `g` into segments of at most `max_size` nodes.
    pub fn partition(
        self,
        g: &Csr,
        max_size: usize,
        rng: &mut Pcg64,
    ) -> SegmentSet {
        let mut set = match self {
            Algorithm::EdgeCutRandom => edge_cut::random(g, max_size, rng),
            Algorithm::EdgeCutBfs => edge_cut::bfs(g, max_size),
            Algorithm::Louvain => louvain::partition(g, max_size, rng),
            Algorithm::MetisLike => metis_like::partition(g, max_size, rng),
            Algorithm::VertexCutRandom => {
                vertex_cut::random(g, max_size, rng)
            }
            Algorithm::VertexCutDbh => vertex_cut::dbh(g, max_size),
            Algorithm::VertexCutNe => vertex_cut::ne(g, max_size, rng),
        };
        enforce_max_size(g, &mut set, max_size);
        set
    }
}

impl SegmentSet {
    /// Number of cut edges (edge-cut) or replicated vertices (vertex-cut) —
    /// the partition-quality metric reported by the partitioners bench.
    pub fn cut_cost(&self, g: &Csr) -> usize {
        match &self.edges {
            None => {
                // edge-cut: edges whose endpoints land in different segments
                let mut part = vec![u32::MAX; g.num_nodes()];
                for (i, seg) in self.segments.iter().enumerate() {
                    for &v in seg {
                        part[v as usize] = i as u32;
                    }
                }
                g.edges()
                    .iter()
                    .filter(|&&(u, v)| part[u as usize] != part[v as usize])
                    .count()
            }
            Some(_) => {
                // vertex-cut: total replicas beyond the first appearance
                let mut seen = vec![0usize; g.num_nodes()];
                for seg in &self.segments {
                    for &v in seg {
                        seen[v as usize] += 1;
                    }
                }
                seen.iter().filter(|&&c| c > 0).map(|&c| c - 1).sum()
            }
        }
    }

    /// Check the partition contract. Returns an error string on violation.
    pub fn validate(&self, g: &Csr, max_size: usize) -> Result<(), String> {
        let n = g.num_nodes();
        let mut count = vec![0usize; n];
        for (i, seg) in self.segments.iter().enumerate() {
            if seg.is_empty() {
                return Err(format!("segment {i} empty"));
            }
            if seg.len() > max_size {
                return Err(format!(
                    "segment {i} has {} nodes > max {max_size}",
                    seg.len()
                ));
            }
            for w in seg.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("segment {i} not sorted/unique"));
                }
            }
            for &v in seg {
                if v as usize >= n {
                    return Err(format!("segment {i}: node {v} out of range"));
                }
                count[v as usize] += 1;
            }
        }
        match &self.edges {
            None => {
                if let Some(v) = count.iter().position(|&c| c != 1) {
                    return Err(format!(
                        "edge-cut: node {v} appears {} times",
                        count[v]
                    ));
                }
            }
            Some(edge_sets) => {
                if let Some(v) = count.iter().position(|&c| c == 0) {
                    return Err(format!("vertex-cut: node {v} uncovered"));
                }
                if edge_sets.len() != self.segments.len() {
                    return Err("edge set / segment count mismatch".into());
                }
                // every original edge exactly once
                let mut all: Vec<(u32, u32)> = edge_sets
                    .iter()
                    .flatten()
                    .map(|&(u, v)| (u.min(v), u.max(v)))
                    .collect();
                all.sort_unstable();
                let mut orig = g.edges();
                orig.sort_unstable();
                if all != orig {
                    return Err("vertex-cut: edge multiset mismatch".into());
                }
            }
        }
        Ok(())
    }
}

/// Fallback guarantee: split any over-size segment into BFS chunks so the
/// AOT fixed shapes always hold, regardless of partitioner quality.
pub(crate) fn enforce_max_size(g: &Csr, set: &mut SegmentSet, max_size: usize) {
    let needs_split = set.segments.iter().any(|s| s.len() > max_size);
    if !needs_split {
        for s in &mut set.segments {
            s.sort_unstable();
            s.dedup();
        }
        return;
    }
    assert!(
        set.edges.is_none() || !needs_split,
        "vertex-cut partitioners must respect max_size internally"
    );
    let mut out = Vec::new();
    for seg in &set.segments {
        if seg.len() <= max_size {
            let mut s = seg.clone();
            s.sort_unstable();
            out.push(s);
            continue;
        }
        // BFS over the induced subgraph, emitting chunks of max_size
        let (sub, map) = g.induced(seg);
        for chunk in edge_cut::bfs(&sub, max_size).segments {
            let mut orig: Vec<u32> =
                chunk.iter().map(|&i| map[i as usize]).collect();
            orig.sort_unstable();
            out.push(orig);
        }
    }
    set.segments = out;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::malnet::{generate_graph, MalnetSplit};
    use crate::testing::prop::{forall, Gen};

    fn test_graph(seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed, 1);
        generate_graph(MalnetSplit::Tiny, (seed % 5) as u8, &mut rng)
    }

    #[test]
    fn all_algorithms_satisfy_contract() {
        for seed in 0..3u64 {
            let g = test_graph(seed);
            for alg in Algorithm::all() {
                let mut rng = Pcg64::new(seed, 7);
                let set = alg.partition(&g, 128, &mut rng);
                set.validate(&g, 128)
                    .unwrap_or_else(|e| panic!("{}: {e}", alg.name()));
            }
        }
    }

    #[test]
    fn prop_max_size_always_respected() {
        forall(
            "segments bounded",
            12,
            Gen::usize(32..256),
            |&max_size| {
                let g = test_graph(max_size as u64);
                Algorithm::all().iter().all(|alg| {
                    let mut rng = Pcg64::new(max_size as u64, 3);
                    let set = alg.partition(&g, max_size, &mut rng);
                    set.segments.iter().all(|s| s.len() <= max_size)
                })
            },
        );
    }

    #[test]
    fn locality_partitioners_beat_random_on_cut() {
        let g = test_graph(1);
        let mut rng = Pcg64::new(5, 5);
        let cut = |alg: Algorithm, rng: &mut Pcg64| {
            alg.partition(&g, 128, rng).cut_cost(&g)
        };
        let random = cut(Algorithm::EdgeCutRandom, &mut rng);
        let metis = cut(Algorithm::MetisLike, &mut rng);
        let louvain = cut(Algorithm::Louvain, &mut rng);
        assert!(
            metis < random / 2,
            "metis-like cut {metis} vs random {random}"
        );
        assert!(
            louvain < random / 2,
            "louvain cut {louvain} vs random {random}"
        );
    }

    #[test]
    fn algorithm_parse_roundtrip() {
        for alg in Algorithm::all() {
            assert!(Algorithm::parse("metis").is_some());
            let _ = alg.name();
        }
        assert_eq!(Algorithm::parse("metis"), Some(Algorithm::MetisLike));
        assert_eq!(Algorithm::parse("nope"), None);
    }
}
