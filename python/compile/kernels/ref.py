"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness ground truth: python/tests/test_kernels.py sweeps
shapes/dtypes with hypothesis and asserts allclose between each kernel and
its oracle here. Keep these boring and obviously-correct — no pallas, no
tiling, just textbook math.
"""

import jax.numpy as jnp

_EPS = 1e-6


def matmul_bias_act_ref(x, w, b, alpha, act: str = "none"):
    out = x @ w + b[None, :]
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    elif act == "prelu":
        out = jnp.where(out >= 0.0, out, alpha[0] * out)
    return out


def adj_matmul_ref(adj, x):
    return jnp.einsum("bij,bjf->bif", adj, x)


def linear_attention_ref(q, k, v, mask):
    q = jnp.maximum(q, 0.0) + _EPS
    k = (jnp.maximum(k, 0.0) + _EPS) * mask[..., None]
    v = v * mask[..., None]
    kv = jnp.einsum("bnh,bnd->bhd", k, v)
    ksum = jnp.sum(k, axis=1)
    num = jnp.einsum("bnh,bhd->bnd", q, kv)
    den = jnp.einsum("bnh,bh->bn", q, ksum) + _EPS
    return num / den[..., None]
