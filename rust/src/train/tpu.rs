//! TpuGraphs trainer: per-graph config *ranking* via pairwise hinge loss
//! and ordered pair accuracy (Table 2, Fig 5).
//!
//! Paper §5.3 specifics honored here:
//! * one 𝒢^(i) = (graph, configuration) — configs are featurized into the
//!   node features, so the table is keyed by (graph, config, segment);
//! * the head is inside F and F' is a parameter-free sum, so the +F
//!   finetuning stage is omitted (GST+EFD = GST+ED here) — and the table
//!   stores scalars (table_dim = 1);
//! * PairwiseHinge within a batch: we batch B configs *of the same graph*
//!   (ranking across graphs is meaningless), with the ordering mask built
//!   from measured runtimes.

use super::ops::{self, BatchBufs};
use super::{Method, RunResult, SedMode, TrainConfig};
use crate::datasets::TpuDataset;
use crate::metrics::{self, Curve, StepTimer};
use crate::runtime::{Engine, ParamStore};
use crate::sed;
use crate::segment::SegmentedGraph;
use crate::table::EmbeddingTable;
use crate::util::rng::Pcg64;
use anyhow::{bail, Result};

pub struct TpuTrainer<'a> {
    eng: &'a Engine,
    data: &'a TpuDataset,
    pub cfg: TrainConfig,
    pub ps: ParamStore,
    /// one partition per graph, shared by all of its configs
    segs: Vec<SegmentedGraph>,
    /// table rows are (graph, config) pairs: row = pair_off[g] + c
    table: EmbeddingTable,
    pair_off: Vec<usize>,
    rng: Pcg64,
    step: u32,
    /// steps recorded during the first epoch (cold-table warmup)
    first_epoch_steps: usize,
    pub timer: StepTimer,
}

impl<'a> TpuTrainer<'a> {
    pub fn new(
        eng: &'a Engine,
        data: &'a TpuDataset,
        cfg: TrainConfig,
    ) -> Result<TpuTrainer<'a>> {
        assert_eq!(eng.manifest.dataset, "tpu");
        if cfg.method == Method::FullGraph {
            bail!(
                "OOM: Full Graph Training on TpuGraphs exceeds the device \
                 budget (paper Table 2) — no full_step artifact is built"
            );
        }
        let mut rng = Pcg64::new(cfg.seed, 0x7965).stream("partition");
        let max = eng.manifest.max_nodes;
        let segs: Vec<SegmentedGraph> = data
            .graphs
            .iter()
            .map(|g| {
                let set = cfg.partition.partition(&g.csr, max, &mut rng);
                SegmentedGraph::new(&g.csr, &set)
            })
            .collect();
        // table: one row-block per (graph, config) pair
        let mut counts = Vec::new();
        let mut pair_off = Vec::with_capacity(data.graphs.len());
        for (gi, g) in data.graphs.iter().enumerate() {
            pair_off.push(counts.len());
            for _ in 0..g.configs.len() {
                counts.push(segs[gi].num_segments());
            }
        }
        let table = EmbeddingTable::new(&counts, eng.manifest.table_dim);
        let ps = ParamStore::load(eng.dir(), &eng.manifest)?;
        eng.warmup(&["grad_step", "apply_step", "embed_fwd"])?;
        Ok(TpuTrainer {
            eng,
            data,
            cfg: cfg.clone(),
            ps,
            segs,
            table,
            pair_off,
            rng: Pcg64::new(cfg.seed, 0x7965),
            step: 0,
            first_epoch_steps: 0,
            timer: StepTimer::default(),
        })
    }

    fn lr(&self) -> f32 {
        self.cfg.lr.unwrap_or(self.eng.manifest.lr)
    }

    fn pair_row(&self, g: usize, c: usize) -> usize {
        self.pair_off[g] + c
    }

    /// Train; metric = mean OPA (train subset / test set).
    pub fn train(&mut self) -> Result<RunResult> {
        let mut curve = Curve::default();
        let eval_train: Vec<usize> =
            self.data.train.iter().take(8).copied().collect();
        for epoch in 0..self.cfg.epochs {
            self.epoch()?;
            if epoch == 0 {
                self.first_epoch_steps = self.timer.count();
            }
            if (epoch + 1) % self.cfg.eval_every == 0
                || epoch + 1 == self.cfg.epochs
            {
                let tr = self.evaluate(&eval_train)?;
                let te = self.evaluate(&self.data.test)?;
                curve.push(epoch + 1, tr, te);
            }
        }
        let train_metric = self.evaluate(&eval_train)?;
        let test_metric = self.evaluate(&self.data.test)?;
        Ok(RunResult {
            train_metric,
            test_metric,
            // steady-state: exclude the first epoch's cold-table steps
            step_ms: self.timer.mean_ms_from(self.first_epoch_steps),
            curve,
            call_counts: self.eng.call_counts(),
        })
    }

    /// One epoch = one ranking step per training graph.
    fn epoch(&mut self) -> Result<()> {
        let mut order = self.data.train.clone();
        let mut rng = self.rng.stream(&format!("epoch{}", self.step));
        rng.shuffle(&mut order);
        let mut micro: Vec<Vec<Vec<f32>>> = Vec::new();
        for &g in &order.clone() {
            self.timer.start();
            let grads = self.rank_step(g, &mut rng)?;
            micro.push(grads);
            if micro.len() == self.cfg.workers {
                let avg = ops::average_grads(&micro);
                let lr = self.lr();
                ops::apply(self.eng, &mut self.ps, &avg, lr)?;
                micro.clear();
            }
            self.timer.stop();
            self.step += 1;
        }
        Ok(())
    }

    /// One grad_step over B configs of graph `g`.
    fn rank_step(&mut self, g: usize, rng: &mut Pcg64) -> Result<Vec<Vec<f32>>> {
        let m = &self.eng.manifest;
        let b = m.batch;
        let graph = &self.data.graphs[g];
        let ncfg = graph.configs.len();
        // B configs, distinct when possible
        let configs: Vec<usize> = if ncfg >= b {
            rng.sample_indices(ncfg, b)
        } else {
            (0..b).map(|i| i % ncfg).collect()
        };
        let j = self.segs[g].num_segments();
        let mut bufs = BatchBufs::new(self.eng);
        let mut sampled = vec![0usize; b];
        let mut fresh: Vec<(usize, usize, f32)> = Vec::new(); // slot, seg, eta
        let mut feats_cache: Vec<Vec<f32>> =
            configs.iter().map(|&c| graph.features_for_config(c)).collect();
        for slot in 0..b {
            let c = configs[slot];
            let s = rng.below(j);
            sampled[slot] = s;
            let w = match self.cfg.method.sed(self.cfg.keep_p) {
                SedMode::KeepAll => sed::keep_all(j, &[s]),
                SedMode::DropAll => sed::drop_all(j, &[s]),
                SedMode::Draw(p) => sed::draw(j, &[s], p, rng),
            };
            bufs.eta[slot] = w.eta_fresh;
            bufs.invj[slot] = 1.0; // sum pooling: no 1/J (paper §5.3)
            let (nodes, adj, mask) = bufs.slot(self.eng, slot);
            self.segs[g].fill_padded(
                &graph.csr, s, m.adj_norm, m.max_nodes, m.feat,
                Some(&feats_cache[slot]), nodes, adj, mask,
            );
            let row = self.pair_row(g, c);
            for (seg, &eta) in w.eta_stale.iter().enumerate() {
                if seg == s || eta == 0.0 {
                    continue;
                }
                if !self.cfg.method.fresh_stale() {
                    if let Some(h) = self.table.get(row, seg) {
                        bufs.stale[slot] += eta * h[0];
                        continue;
                    }
                }
                fresh.push((slot, seg, eta));
            }
            // pairwise ordering mask within the batch (same graph)
            for other in 0..b {
                if graph.runtimes[c] > graph.runtimes[configs[other]] {
                    bufs.pair[slot * b + other] = 1.0;
                }
            }
        }
        if !fresh.is_empty() {
            let items: Vec<(usize, usize, usize)> = fresh
                .iter()
                .map(|&(slot, seg, _)| (g, configs[slot], seg))
                .collect();
            let embs = self.embed_many(&items, Some(&mut feats_cache))?;
            for ((slot, seg, eta), h) in fresh.iter().zip(&embs) {
                bufs.stale[*slot] += eta * h[0];
                if self.cfg.method.uses_table() {
                    self.table.put(
                        self.pair_row(g, configs[*slot]), *seg, h, self.step,
                    );
                }
            }
        }
        let out = ops::grad_step(self.eng, &self.ps, &bufs)?;
        if self.cfg.method.uses_table() {
            for slot in 0..b {
                let h = &out.h_s[slot..slot + 1];
                self.table.put(
                    self.pair_row(g, configs[slot]), sampled[slot], h,
                    self.step,
                );
            }
        }
        Ok(out.grads)
    }

    /// Fresh per-segment runtime contributions for (graph, config, seg)
    /// triples. `feats_hint` is an optional cache keyed by slot order.
    fn embed_many(
        &self,
        items: &[(usize, usize, usize)],
        _feats_hint: Option<&mut Vec<Vec<f32>>>,
    ) -> Result<Vec<Vec<f32>>> {
        let m = &self.eng.manifest;
        let (b, n, f, td) = (m.batch, m.max_nodes, m.feat, m.table_dim);
        let mut out = Vec::with_capacity(items.len());
        let mut nodes = vec![0f32; b * n * f];
        let mut adj = vec![0f32; b * n * n];
        let mut mask = vec![0f32; b * n];
        // cache config feature materializations within this call
        let mut cache: std::collections::HashMap<(usize, usize), Vec<f32>> =
            std::collections::HashMap::new();
        for chunk in items.chunks(b) {
            for slot in 0..b {
                let (g, c, s) = chunk[slot.min(chunk.len() - 1)];
                let feats = cache
                    .entry((g, c))
                    .or_insert_with(|| {
                        self.data.graphs[g].features_for_config(c)
                    })
                    .clone();
                self.segs[g].fill_padded(
                    &self.data.graphs[g].csr, s, m.adj_norm, n, f,
                    Some(&feats),
                    &mut nodes[slot * n * f..(slot + 1) * n * f],
                    &mut adj[slot * n * n..(slot + 1) * n * n],
                    &mut mask[slot * n..(slot + 1) * n],
                );
            }
            let h = ops::embed_fwd(self.eng, &self.ps, &nodes, &adj, &mask)?;
            for slot in 0..chunk.len() {
                out.push(h[slot * td..(slot + 1) * td].to_vec());
            }
        }
        Ok(out)
    }

    /// Mean OPA over `graphs`: predicted runtime of each config = Σ_j r_j
    /// with fresh embeddings (F' = sum, paper §5.3).
    pub fn evaluate(&self, graphs: &[usize]) -> Result<f64> {
        let mut per_graph = Vec::with_capacity(graphs.len());
        for &g in graphs {
            let graph = &self.data.graphs[g];
            let j = self.segs[g].num_segments();
            let mut items = Vec::new();
            for c in 0..graph.configs.len() {
                for s in 0..j {
                    items.push((g, c, s));
                }
            }
            let embs = self.embed_many(&items, None)?;
            let mut yhat = vec![0f32; graph.configs.len()];
            for ((_, c, _), h) in items.iter().zip(&embs) {
                yhat[*c] += h[0];
            }
            per_graph.push((yhat, graph.runtimes.clone()));
        }
        Ok(metrics::mean_opa(&per_graph))
    }
}
