//! `gst` — command-line launcher for the Graph Segment Training framework.
//!
//! Subcommands:
//!   experiment   regenerate a paper table/figure (see DESIGN.md §5)
//!   train        one training run with explicit flags
//!   analyze      trace/report analytics (critical path, drift, workers),
//!                trace diffing (--diff) and the CI trend ring (--trend)
//!   diff-report  compare two run reports; the CI perf-regression gate
//!   data-stats   print synthetic dataset statistics (Table 4 shape)
//!   partition    partition quality report across algorithms
//!   memory       paper-scale memory model report (the OOM boundary)

use anyhow::{anyhow, bail, Context, Result};
use gst::datasets::{MalnetDataset, MalnetSplit, TpuDataset};
use gst::exp::{self, common::Env};
use gst::graph::GraphStats;
use gst::memory::MemoryModel;
use gst::obs::analyze;
use gst::partition::Algorithm;
use gst::train::{MalnetTrainer, Method, TpuTrainer, TrainConfig};
use gst::util::cli::Cli;
use gst::util::json::Json;
use gst::util::rng::Pcg64;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("{e}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        bail!(usage());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "experiment" => cmd_experiment(rest),
        "train" => cmd_train(rest),
        "analyze" => cmd_analyze(rest),
        "diff-report" => cmd_diff_report(rest),
        "data-stats" => cmd_data_stats(rest),
        "partition" => cmd_partition(rest),
        "memory" => cmd_memory(),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown command `{other}`\n{}", usage()),
    }
}

fn usage() -> String {
    format!(
        "gst — Graph Segment Training (NeurIPS 2023 reproduction)\n\n\
         USAGE: gst <command> [flags]\n\n\
         COMMANDS:\n\
         \x20 experiment --id <{}|all> [--quick] [--artifacts DIR] [--out DIR]\n\
         \x20 train --dataset <malnet-tiny|malnet-large|tpu> --method <full|gst|gst-one|gst+e|gst+ef|gst+ed|gst+efd>\n\
         \x20       [--backbone gcn|sage|gps] [--epochs N] [--keep-p P] [--partition ALG] [--seed S]\n\
         \x20       [--micro-batches M] [--workers W] [--fill-cache-mb MB] [--curve]\n\
         \x20       [--report-json FILE] [--trace-out FILE] [--log-every N]\n\
         \x20 analyze --trace FILE | --report FILE [--top N] [--json FILE]\n\
         \x20 analyze --diff <base.jsonl> <cand.jsonl> [--slow-step-pct PCT] [--json FILE]\n\
         \x20 analyze --trend RING [--append REPORT --label L --cap N] [--json FILE]\n\
         \x20 diff-report <baseline.json> <candidate.json> [--fail-on-regression PCT] [--json FILE]\n\
         \x20 data-stats [--graphs N]\n\
         \x20 partition [--alg ALG] [--max-size N]\n\
         \x20 memory",
        exp::ALL_IDS.join("|")
    )
}

fn cmd_experiment(argv: &[String]) -> Result<()> {
    let cli = Cli::new("gst experiment", "regenerate a paper table/figure")
        .opt("id", None, "experiment id or `all`")
        .opt("artifacts", Some("artifacts"), "AOT artifact root")
        .opt("out", Some("runs"), "output directory for JSON records")
        .switch("quick", "small sizing for smoke runs");
    let args = cli.parse(argv).map_err(|e| anyhow!(e))?;
    let id = args.get("id").ok_or_else(|| anyhow!("--id required"))?;
    let env = Env::new(
        args.get("artifacts").unwrap(),
        args.get("out").unwrap(),
        args.get_bool("quick"),
    )?;
    if id == "all" {
        for id in exp::ALL_IDS {
            exp::run(id, &env)?;
        }
        Ok(())
    } else {
        exp::run(id, &env)
    }
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let cli = Cli::new("gst train", "one training run")
        .opt("dataset", Some("malnet-tiny"), "malnet-tiny|malnet-large|tpu")
        .opt("method", Some("gst+efd"), "training method")
        .opt("backbone", Some("sage"), "gcn|sage|gps (malnet only)")
        .opt("epochs", Some("10"), "training epochs")
        .opt("finetune-epochs", Some("4"), "+F epochs")
        .opt("keep-p", Some("0.5"), "SED keep probability")
        .opt("partition", Some("metis"), "partition algorithm")
        .opt("seed", Some("0"), "RNG seed")
        .opt(
            "micro-batches",
            Some("1"),
            "micro-batches (simulated devices) averaged per step",
        )
        .opt("workers", Some("1"), "worker threads (execution only)")
        .opt(
            "fill-cache-mb",
            Some("0"),
            "padded fill-block cache budget, MiB (execution only)",
        )
        .opt("graphs", Some("60"), "synthetic dataset size")
        .opt("artifacts", Some("artifacts"), "AOT artifact root")
        .opt("max-nodes", Some("128"), "segment size variant (32|64|128|256)")
        .opt("lr", None, "override learning rate")
        .opt("report-json", None, "write the machine-readable run report")
        .opt("trace-out", None, "stream JSONL span/point events to FILE")
        .opt("log-every", Some("0"), "heartbeat every N steps (0 = off)")
        .switch("curve", "print the per-epoch accuracy curve");
    let args = cli.parse(argv).map_err(|e| anyhow!(e))?;
    let method = Method::parse(args.get("method").unwrap())
        .ok_or_else(|| anyhow!("bad --method"))?;
    let obs = gst::obs::ObsConfig {
        record: args.get("report-json").is_some(),
        trace_out: args.get("trace-out").map(|s| s.to_string()),
        log_every: args.get_usize("log-every").map_err(|e| anyhow!(e))?,
    };
    let cfg = TrainConfig {
        method,
        epochs: args.get_usize("epochs").map_err(|e| anyhow!(e))?,
        finetune_epochs: args
            .get_usize("finetune-epochs")
            .map_err(|e| anyhow!(e))?,
        keep_p: args.get_f64("keep-p").map_err(|e| anyhow!(e))? as f32,
        s_per_graph: 1,
        workers: args.get_usize("workers").map_err(|e| anyhow!(e))?,
        micro_batches: args
            .get_usize("micro-batches")
            .map_err(|e| anyhow!(e))?,
        seed: args.get_usize("seed").map_err(|e| anyhow!(e))? as u64,
        partition: Algorithm::parse(args.get("partition").unwrap())
            .ok_or_else(|| anyhow!("bad --partition"))?,
        eval_every: 1,
        lr: args.get("lr").and_then(|s| s.parse::<f32>().ok()),
        fill_cache_mb: args
            .get_usize("fill-cache-mb")
            .map_err(|e| anyhow!(e))?,
        obs,
        shared_fill_cache: true,
        batched_writeback: true,
    };
    let count = args.get_usize("graphs").map_err(|e| anyhow!(e))?;
    let root = args.get("artifacts").unwrap();
    let nmax = args.get_usize("max-nodes").map_err(|e| anyhow!(e))?;
    let dataset = args.get("dataset").unwrap();
    let (metric, res) = match dataset {
        "tpu" => {
            let eng = gst::runtime::Engine::open(&format!(
                "{root}/tpu_sage_n{nmax}"
            ))?;
            let data = TpuDataset::generate(count, 8, cfg.seed + 2000);
            let mut tr = TpuTrainer::new(&eng, &data, cfg)?;
            ("opa", tr.train()?)
        }
        split @ ("malnet-tiny" | "malnet-large") => {
            let backbone = args.get("backbone").unwrap();
            let eng = gst::runtime::Engine::open(&format!(
                "{root}/malnet_{backbone}_n{nmax}"
            ))?;
            let split = if split == "malnet-tiny" {
                MalnetSplit::Tiny
            } else {
                MalnetSplit::Large
            };
            let data = MalnetDataset::generate(split, count, cfg.seed + 1000);
            let mut tr = MalnetTrainer::new(&eng, &data, cfg)?;
            ("acc", tr.train()?)
        }
        other => bail!("unknown dataset `{other}`"),
    };
    print_run_summary(metric, method, &res, args.get_bool("curve"));
    if let Some(path) = args.get("report-json") {
        std::fs::write(path, res.report.to_string())
            .with_context(|| format!("writing report {path}"))?;
        println!("  report written to {path}");
    }
    Ok(())
}

fn cmd_analyze(argv: &[String]) -> Result<()> {
    let cli = Cli::new("gst analyze", "trace/report/trend analytics")
        .opt("trace", None, "JSONL trace from `gst train --trace-out`")
        .opt("report", None, "run report from `gst train --report-json`")
        .switch(
            "diff",
            "diff two traces (positional: base.jsonl cand.jsonl) and \
             localize the regression by step range and phase",
        )
        .opt(
            "slow-step-pct",
            Some("20"),
            "--diff: a step counts as regressed past this percent",
        )
        .opt("trend", None, "trend ring file to analyze (and append to)")
        .opt(
            "append",
            None,
            "--trend: sample this run report into the ring first",
        )
        .opt("label", Some("run"), "--append: label for the new entry")
        .opt("cap", Some("50"), "--append: max ring entries before rotation")
        .opt("top", Some("5"), "slowest steps to list")
        .opt("json", None, "also write the analysis document to FILE");
    let args = cli.parse(argv).map_err(|e| anyhow!(e))?;
    let top = args.get_usize("top").map_err(|e| anyhow!(e))?;
    let (doc, text) = if args.get_bool("diff") {
        let [base_path, cand_path] = args.positional.as_slice() else {
            bail!(
                "usage: gst analyze --diff <base.jsonl> <candidate.jsonl>"
            );
        };
        let pct =
            args.get_f64("slow-step-pct").map_err(|e| anyhow!(e))?;
        let base = std::fs::read_to_string(base_path)
            .with_context(|| format!("reading trace {base_path}"))?;
        let cand = std::fs::read_to_string(cand_path)
            .with_context(|| format!("reading trace {cand_path}"))?;
        let doc = analyze::diff_traces(&base, &cand, pct)
            .map_err(|e| anyhow!(e))?;
        let text = analyze::render_trace_diff(&doc);
        (doc, text)
    } else if let Some(ring_path) = args.get("trend") {
        // a missing ring file starts fresh only when appending
        let mut ring = match std::fs::read_to_string(ring_path) {
            Ok(text) => Json::parse(&text)
                .map_err(|e| anyhow!("parsing {ring_path}: {e}"))?,
            Err(_) if args.get("append").is_some() => analyze::trend_new(
                args.get_usize("cap").map_err(|e| anyhow!(e))?,
            ),
            Err(e) => bail!("reading ring {ring_path}: {e}"),
        };
        if let Some(report_path) = args.get("append") {
            let report = read_json(report_path)?;
            ring = analyze::trend_append(
                &ring,
                &report,
                args.get("label").unwrap(),
                args.get_usize("cap").map_err(|e| anyhow!(e))?,
            )
            .map_err(|e| anyhow!(e))?;
            std::fs::write(ring_path, ring.to_string())
                .with_context(|| format!("writing ring {ring_path}"))?;
            println!("ring updated: {ring_path}");
        }
        let doc =
            analyze::trend_analyze(&ring).map_err(|e| anyhow!(e))?;
        let text = analyze::render_trend(&doc);
        (doc, text)
    } else {
        let doc = match (args.get("trace"), args.get("report")) {
            (Some(path), None) => {
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading trace {path}"))?;
                analyze::analyze_trace(&text, top)
                    .map_err(|e| anyhow!(e))?
            }
            (None, Some(path)) => {
                let report = read_json(path)?;
                analyze::analyze_report(&report).map_err(|e| anyhow!(e))?
            }
            _ => bail!(
                "pass one of --trace FILE, --report FILE, \
                 --diff <base> <cand>, or --trend RING"
            ),
        };
        let text = analyze::render_analysis(&doc);
        (doc, text)
    };
    print!("{text}");
    if let Some(path) = args.get("json") {
        std::fs::write(path, doc.to_string())
            .with_context(|| format!("writing analysis {path}"))?;
        println!("analysis written to {path}");
    }
    Ok(())
}

fn cmd_diff_report(argv: &[String]) -> Result<()> {
    let cli = Cli::new(
        "gst diff-report",
        "compare two run reports (the CI perf-regression gate)",
    )
    .opt(
        "fail-on-regression",
        Some("20"),
        "exit 1 when a field regressed by more than PCT percent",
    )
    .opt("json", None, "also write the diff document to FILE");
    let args = cli.parse(argv).map_err(|e| anyhow!(e))?;
    let [base_path, cand_path] = args.positional.as_slice() else {
        bail!("usage: gst diff-report <baseline.json> <candidate.json>");
    };
    let pct =
        args.get_f64("fail-on-regression").map_err(|e| anyhow!(e))?;
    let base = read_json(base_path)?;
    let cand = read_json(cand_path)?;
    let diff = analyze::diff_reports(&base, &cand, pct)
        .map_err(|e| anyhow!(e))?;
    print!("{}", analyze::render_diff(&diff));
    if let Some(path) = args.get("json") {
        std::fs::write(path, diff.to_string())
            .with_context(|| format!("writing diff {path}"))?;
        println!("diff written to {path}");
    }
    if diff.get("pass").and_then(|p| p.as_bool()) != Some(true) {
        bail!(
            "performance regression beyond {pct}% against {base_path}"
        );
    }
    Ok(())
}

fn read_json(path: &str) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path}"))?;
    Json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))
}

/// One summary printer for every dataset arm (identical output shape
/// whether the run ranked TPU configs or classified malnet graphs).
fn print_run_summary(
    metric: &str,
    method: Method,
    res: &gst::train::RunResult,
    curve: bool,
) {
    if curve {
        for i in 0..res.curve.epochs.len() {
            println!(
                "epoch {:>4}  train {:.4}  test {:.4}",
                res.curve.epochs[i], res.curve.train[i], res.curve.test[i]
            );
        }
    }
    println!(
        "method={} train_{metric}={:.4} test_{metric}={:.4} \
         step_ms={:.1} p95_ms={:.1} max_ms={:.1}",
        method.name(),
        res.train_metric,
        res.test_metric,
        res.step_ms,
        res.step_p95_ms,
        res.step_max_ms
    );
    let mut counts: Vec<_> = res.call_counts.iter().collect();
    counts.sort();
    for (k, v) in counts {
        println!("  calls {k}: {v}");
    }
    if res.fill_cache.total() > 0 {
        println!(
            "  fill-cache hits: {}/{} ({:.1}%)",
            res.fill_cache.hits,
            res.fill_cache.total(),
            100.0 * res.fill_cache.hit_rate()
        );
    }
    if res.param_cache.total() > 0 {
        println!(
            "  param-literal cache hits: {}/{} ({:.1}%)",
            res.param_cache.hits,
            res.param_cache.total(),
            100.0 * res.param_cache.hit_rate()
        );
    }
}

fn cmd_data_stats(argv: &[String]) -> Result<()> {
    let cli = Cli::new("gst data-stats", "synthetic dataset statistics")
        .opt("graphs", Some("60"), "graphs per dataset");
    let args = cli.parse(argv).map_err(|e| anyhow!(e))?;
    let n = args.get_usize("graphs").map_err(|e| anyhow!(e))?;
    println!("{}", GraphStats::header());
    let tiny = MalnetDataset::generate(MalnetSplit::Tiny, n, 1000);
    println!("{}", GraphStats::over(&tiny.graphs).row("malnet-tiny"));
    let large = MalnetDataset::generate(MalnetSplit::Large, n.min(48), 1000);
    println!("{}", GraphStats::over(&large.graphs).row("malnet-large"));
    let tpu = TpuDataset::generate(n.min(24), 8, 2000);
    let gs: Vec<_> = tpu.graphs.iter().map(|g| g.csr.clone()).collect();
    println!("{}", GraphStats::over(&gs).row("tpugraphs"));
    Ok(())
}

fn cmd_partition(argv: &[String]) -> Result<()> {
    let cli = Cli::new("gst partition", "partition quality report")
        .opt("alg", Some("all"), "algorithm or `all`")
        .opt("max-size", Some("128"), "max segment size")
        .opt("graphs", Some("10"), "sample graphs");
    let args = cli.parse(argv).map_err(|e| anyhow!(e))?;
    let max = args.get_usize("max-size").map_err(|e| anyhow!(e))?;
    let n = args.get_usize("graphs").map_err(|e| anyhow!(e))?;
    let data = MalnetDataset::generate(MalnetSplit::Tiny, n, 1000);
    let algs: Vec<Algorithm> = match args.get("alg").unwrap() {
        "all" => Algorithm::all().to_vec(),
        a => vec![Algorithm::parse(a).ok_or_else(|| anyhow!("bad --alg"))?],
    };
    println!(
        "{:<22} {:>10} {:>10} {:>12} {:>10}",
        "algorithm", "segments", "cut-cost", "largest-seg", "ms"
    );
    for alg in algs {
        let mut rng = Pcg64::new(7, 7);
        let t0 = std::time::Instant::now();
        let (mut segs, mut cut, mut largest) = (0usize, 0usize, 0usize);
        for g in &data.graphs {
            let set = alg.partition(g, max, &mut rng);
            segs += set.segments.len();
            cut += set.cut_cost(g);
            largest = largest
                .max(set.segments.iter().map(|s| s.len()).max().unwrap_or(0));
        }
        println!(
            "{:<22} {:>10} {:>10} {:>12} {:>10.1}",
            alg.name(),
            segs,
            cut,
            largest,
            t0.elapsed().as_secs_f64() * 1e3
        );
    }
    Ok(())
}

fn cmd_memory() -> Result<()> {
    println!("paper-scale activation-memory model (V100 16 GB, hidden 300):");
    let m = MemoryModel::malnet_paper("sage");
    let rows: [(&str, Vec<(usize, usize)>); 3] = [
        ("malnet-tiny  batch=16", vec![(1_410, 2_860); 16]),
        ("malnet-large batch=16", vec![(47_838, 225_474); 16]),
        ("malnet-large worst graph", vec![(541_571, 3_278_318)]),
    ];
    for (name, batch) in rows {
        let peak = m.full_graph_peak(&batch);
        println!(
            "  full-graph {name:<28} {:>8.2} GiB  {}",
            peak as f64 / (1u64 << 30) as f64,
            if m.full_graph_ooms(&batch) { "OOM" } else { "fits" }
        );
    }
    let gst = m.gst_peak_bytes(16, 1, 5_000, 20_000);
    println!(
        "  GST (any split, max-seg 5k)          {:>8.2} GiB  fits — \
         constant in graph size",
        gst as f64 / (1u64 << 30) as f64
    );
    Ok(())
}
