//! Segment extraction and padding to the AOT fixed shapes.
//!
//! The L2 model consumes `(nodes [B,N,F], adj [B,N,N], mask [B,N])` with a
//! **dense normalized adjacency** per segment — the TPU hardware adaptation
//! (DESIGN.md §Hardware-Adaptation): GST's bounded segment size makes the
//! dense N×N block small enough for VMEM, turning message passing into MXU
//! matmuls instead of gather/scatter.
//!
//! [`SegmentedGraph`] holds one parent graph's segments (node lists, or
//! explicit edge sets for vertex-cut) and fills caller-provided padded
//! buffers on demand — no per-fetch allocation on the training hot path.
//!
//! [`PreparedSegments`] goes one step further for the steady state: the
//! adjacency normalization and the packed feature block are invariant
//! across a run, so it precomputes them once per graph and reduces each
//! fill to memcpy + sparse scatter (no degree recomputation, no sqrt /
//! divides, no per-call allocation). [`FillCache`] sits on top and serves
//! the hottest segments' fully padded tensors directly. Both paths are
//! bit-identical to [`SegmentedGraph::fill_padded`] — pinned by a
//! property test — so they are pure execution knobs.

pub mod fill_cache;

pub use fill_cache::{FillCache, FillHandle};

use crate::graph::Csr;
use crate::partition::SegmentSet;

/// Which normalized adjacency the backbone expects (from the manifest).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdjNorm {
    /// GCN: D^-1/2 (A + I) D^-1/2
    SymSelfLoop,
    /// SAGE/GPS: D^-1 A (row mean, no self loops)
    RowMean,
}

impl AdjNorm {
    pub fn parse(s: &str) -> Option<AdjNorm> {
        match s {
            "sym_selfloop" => Some(AdjNorm::SymSelfLoop),
            "row_mean" => Some(AdjNorm::RowMean),
            _ => None,
        }
    }
}

/// One parent graph cut into segments.
pub struct SegmentedGraph {
    /// Sorted node ids per segment.
    pub segments: Vec<Vec<u32>>,
    /// Intra-segment edges in *local* (segment-relative) indices.
    pub local_edges: Vec<Vec<(u16, u16)>>,
}

impl SegmentedGraph {
    /// Build from a partitioner output. Edge-cut sets use the induced
    /// subgraph; vertex-cut sets use their explicit edge lists.
    pub fn new(g: &Csr, set: &SegmentSet) -> SegmentedGraph {
        let mut local_edges = Vec::with_capacity(set.segments.len());
        for (si, seg) in set.segments.iter().enumerate() {
            let mut rank = std::collections::HashMap::new();
            for (i, &v) in seg.iter().enumerate() {
                rank.insert(v, i as u16);
            }
            let mut edges = Vec::new();
            match &set.edges {
                Some(per_seg) => {
                    for &(u, v) in &per_seg[si] {
                        edges.push((rank[&u], rank[&v]));
                    }
                }
                None => {
                    for (i, &v) in seg.iter().enumerate() {
                        for &w in g.neighbors(v as usize) {
                            if let Some(&j) = rank.get(&w) {
                                if (i as u16) < j {
                                    edges.push((i as u16, j));
                                }
                            }
                        }
                    }
                }
            }
            local_edges.push(edges);
        }
        SegmentedGraph { segments: set.segments.clone(), local_edges }
    }

    pub fn num_segments(&self) -> usize {
        self.segments.len()
    }

    /// Fill one padded slot of a batch. `feats_override` substitutes the
    /// parent graph's features (used by TpuGraphs to bake config one-hots).
    ///
    /// * `nodes_out`: N*F slice, zero-padded
    /// * `adj_out`: N*N slice, normalized per `norm`, zero outside the
    ///   real block
    /// * `mask_out`: N slice of {0,1}
    #[allow(clippy::too_many_arguments)]
    pub fn fill_padded(
        &self,
        g: &Csr,
        seg_idx: usize,
        norm: AdjNorm,
        max_nodes: usize,
        feat_dim: usize,
        feats_override: Option<&[f32]>,
        nodes_out: &mut [f32],
        adj_out: &mut [f32],
        mask_out: &mut [f32],
    ) {
        let seg = &self.segments[seg_idx];
        let n = seg.len();
        assert!(n <= max_nodes, "segment {n} > padded {max_nodes}");
        assert_eq!(nodes_out.len(), max_nodes * feat_dim);
        assert_eq!(adj_out.len(), max_nodes * max_nodes);
        assert_eq!(mask_out.len(), max_nodes);
        nodes_out.fill(0.0);
        adj_out.fill(0.0);
        mask_out.fill(0.0);
        let feats = feats_override.unwrap_or(&g.feats);
        let fdim = g.feat_dim.min(feat_dim);
        for (i, &v) in seg.iter().enumerate() {
            let src = &feats[v as usize * g.feat_dim..][..fdim];
            nodes_out[i * feat_dim..i * feat_dim + fdim].copy_from_slice(src);
            mask_out[i] = 1.0;
        }
        // degree within the segment
        let mut deg = vec![0f32; n];
        for &(u, v) in &self.local_edges[seg_idx] {
            deg[u as usize] += 1.0;
            deg[v as usize] += 1.0;
        }
        match norm {
            AdjNorm::SymSelfLoop => {
                // Â = D^-1/2 (A+I) D^-1/2 with D including the self loop
                let inv_sqrt: Vec<f32> =
                    deg.iter().map(|&d| 1.0 / (d + 1.0).sqrt()).collect();
                for i in 0..n {
                    adj_out[i * max_nodes + i] = inv_sqrt[i] * inv_sqrt[i];
                }
                for &(u, v) in &self.local_edges[seg_idx] {
                    let (u, v) = (u as usize, v as usize);
                    let w = inv_sqrt[u] * inv_sqrt[v];
                    adj_out[u * max_nodes + v] = w;
                    adj_out[v * max_nodes + u] = w;
                }
            }
            AdjNorm::RowMean => {
                for &(u, v) in &self.local_edges[seg_idx] {
                    let (u, v) = (u as usize, v as usize);
                    adj_out[u * max_nodes + v] = 1.0 / deg[u].max(1.0);
                    adj_out[v * max_nodes + u] = 1.0 / deg[v].max(1.0);
                }
            }
        }
    }
}

/// Per-segment fill data precomputed once per [`SegmentedGraph`]: the
/// normalized directed edge weights for one [`AdjNorm`], the diagonal
/// terms, and the packed node-feature block. Steady-state fills become
/// pure memcpy + sparse scatter — no degree vector, no sqrt/divides.
///
/// The weights are computed with exactly the expressions
/// [`SegmentedGraph::fill_padded`] uses, so [`PreparedSegments::fill`] is
/// bit-identical to the direct path (the property test pins this).
pub struct PreparedSegments {
    max_nodes: usize,
    feat_dim: usize,
    /// features copied per node: min(graph feat_dim, padded feat_dim)
    src_fdim: usize,
    /// row stride of the parent (or override) feature buffer
    src_stride: usize,
    /// node ids per segment (the gather map for feature overrides)
    node_ids: Vec<Vec<u32>>,
    /// packed base features per segment: len·feat_dim, tail zeroed
    feats: Vec<Vec<f32>>,
    /// directed normalized entries: adj[u·max_nodes + v] = w
    edges: Vec<Vec<(u16, u16, f32)>>,
    /// diagonal per real node (SymSelfLoop only; empty for RowMean)
    diag: Vec<Vec<f32>>,
}

impl PreparedSegments {
    pub fn new(
        g: &Csr,
        sg: &SegmentedGraph,
        norm: AdjNorm,
        max_nodes: usize,
        feat_dim: usize,
    ) -> PreparedSegments {
        let src_fdim = g.feat_dim.min(feat_dim);
        let mut node_ids = Vec::with_capacity(sg.num_segments());
        let mut feats = Vec::with_capacity(sg.num_segments());
        let mut edges = Vec::with_capacity(sg.num_segments());
        let mut diag = Vec::with_capacity(sg.num_segments());
        for (si, seg) in sg.segments.iter().enumerate() {
            let n = seg.len();
            let mut packed = vec![0f32; n * feat_dim];
            for (i, &v) in seg.iter().enumerate() {
                let src = &g.feats[v as usize * g.feat_dim..][..src_fdim];
                packed[i * feat_dim..i * feat_dim + src_fdim]
                    .copy_from_slice(src);
            }
            let local = &sg.local_edges[si];
            let mut deg = vec![0f32; n];
            for &(u, v) in local {
                deg[u as usize] += 1.0;
                deg[v as usize] += 1.0;
            }
            let mut dir = Vec::with_capacity(local.len() * 2);
            let d = match norm {
                AdjNorm::SymSelfLoop => {
                    let inv_sqrt: Vec<f32> =
                        deg.iter().map(|&d| 1.0 / (d + 1.0).sqrt()).collect();
                    for &(u, v) in local {
                        let w =
                            inv_sqrt[u as usize] * inv_sqrt[v as usize];
                        dir.push((u, v, w));
                        dir.push((v, u, w));
                    }
                    inv_sqrt.iter().map(|&s| s * s).collect()
                }
                AdjNorm::RowMean => {
                    for &(u, v) in local {
                        dir.push((u, v, 1.0 / deg[u as usize].max(1.0)));
                        dir.push((v, u, 1.0 / deg[v as usize].max(1.0)));
                    }
                    Vec::new()
                }
            };
            node_ids.push(seg.clone());
            feats.push(packed);
            edges.push(dir);
            diag.push(d);
        }
        PreparedSegments {
            max_nodes,
            feat_dim,
            src_fdim,
            src_stride: g.feat_dim,
            node_ids,
            feats,
            edges,
            diag,
        }
    }

    pub fn num_segments(&self) -> usize {
        self.node_ids.len()
    }

    pub fn seg_len(&self, seg_idx: usize) -> usize {
        self.node_ids[seg_idx].len()
    }

    /// Heap bytes held by the prepared data (perf accounting).
    pub fn bytes(&self) -> usize {
        self.node_ids.iter().map(|v| v.len() * 4).sum::<usize>()
            + self.feats.iter().map(|v| v.len() * 4).sum::<usize>()
            + self.edges.iter().map(|v| v.len() * 8).sum::<usize>()
            + self.diag.iter().map(|v| v.len() * 4).sum::<usize>()
    }

    /// Drop-in replacement for [`SegmentedGraph::fill_padded`] over the
    /// prepared data (same buffer contract, bit-identical output).
    pub fn fill(
        &self,
        seg_idx: usize,
        feats_override: Option<&[f32]>,
        nodes_out: &mut [f32],
        adj_out: &mut [f32],
        mask_out: &mut [f32],
    ) {
        let n = self.node_ids[seg_idx].len();
        let (max_nodes, fd) = (self.max_nodes, self.feat_dim);
        assert!(n <= max_nodes, "segment {n} > padded {max_nodes}");
        assert_eq!(nodes_out.len(), max_nodes * fd);
        assert_eq!(adj_out.len(), max_nodes * max_nodes);
        assert_eq!(mask_out.len(), max_nodes);
        nodes_out.fill(0.0);
        adj_out.fill(0.0);
        mask_out.fill(0.0);
        match feats_override {
            None => nodes_out[..n * fd].copy_from_slice(&self.feats[seg_idx]),
            Some(feats) => {
                for (i, &v) in self.node_ids[seg_idx].iter().enumerate() {
                    let src =
                        &feats[v as usize * self.src_stride..][..self.src_fdim];
                    nodes_out[i * fd..i * fd + self.src_fdim]
                        .copy_from_slice(src);
                }
            }
        }
        mask_out[..n].fill(1.0);
        for &(u, v, w) in &self.edges[seg_idx] {
            adj_out[u as usize * max_nodes + v as usize] = w;
        }
        for (i, &w) in self.diag[seg_idx].iter().enumerate() {
            adj_out[i * max_nodes + i] = w;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::partition::SegmentSet;

    fn path4() -> Csr {
        let mut b = GraphBuilder::new(4, 2);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(2, 3);
        for v in 0..4 {
            b.set_feat(v, &[v as f32, 1.0]);
        }
        b.build()
    }

    fn two_segments() -> SegmentSet {
        SegmentSet { segments: vec![vec![0, 1], vec![2, 3]], edges: None }
    }

    #[test]
    fn local_edges_from_induced() {
        let g = path4();
        let sg = SegmentedGraph::new(&g, &two_segments());
        assert_eq!(sg.local_edges[0], vec![(0, 1)]);
        assert_eq!(sg.local_edges[1], vec![(0, 1)]);
        // the cut edge 1-2 is dropped (the paper's ⊕ approximation)
    }

    #[test]
    fn vertex_cut_edges_respected() {
        let g = path4();
        let set = SegmentSet {
            segments: vec![vec![0, 1, 2], vec![2, 3]],
            edges: Some(vec![vec![(0, 1), (1, 2)], vec![(2, 3)]]),
        };
        let sg = SegmentedGraph::new(&g, &set);
        assert_eq!(sg.local_edges[0], vec![(0, 1), (1, 2)]);
        assert_eq!(sg.local_edges[1], vec![(0, 1)]);
    }

    #[test]
    fn padding_layout_and_mask() {
        let g = path4();
        let sg = SegmentedGraph::new(&g, &two_segments());
        let (n, f) = (3usize, 2usize);
        let mut nodes = vec![9.0; n * f];
        let mut adj = vec![9.0; n * n];
        let mut mask = vec![9.0; n];
        sg.fill_padded(&g, 1, AdjNorm::RowMean, n, f, None, &mut nodes,
                       &mut adj, &mut mask);
        assert_eq!(mask, vec![1.0, 1.0, 0.0]);
        assert_eq!(&nodes[..4], &[2.0, 1.0, 3.0, 1.0]);
        assert_eq!(&nodes[4..], &[0.0, 0.0]); // padded row zeroed
        // row-mean: both real nodes have in-segment degree 1
        assert_eq!(adj[0 * n + 1], 1.0);
        assert_eq!(adj[1 * n + 0], 1.0);
        assert_eq!(adj[2 * n + 2], 0.0); // no self loop on padding
    }

    #[test]
    fn sym_selfloop_rows_normalized() {
        let g = path4();
        let sg = SegmentedGraph::new(&g, &two_segments());
        let n = 4usize;
        let mut nodes = vec![0.0; n * 2];
        let mut adj = vec![0.0; n * n];
        let mut mask = vec![0.0; n];
        sg.fill_padded(&g, 0, AdjNorm::SymSelfLoop, n, 2, None, &mut nodes,
                       &mut adj, &mut mask);
        // deg+1 = 2 for both nodes: diagonal 1/2, off-diagonal 1/2
        assert!((adj[0] - 0.5).abs() < 1e-6);
        assert!((adj[1] - 0.5).abs() < 1e-6);
        assert!((adj[n + 1] - 0.5).abs() < 1e-6);
        // padded diagonal stays zero
        assert_eq!(adj[2 * n + 2], 0.0);
    }

    #[test]
    fn feats_override_used() {
        let g = path4();
        let sg = SegmentedGraph::new(&g, &two_segments());
        let alt = vec![7.0f32; 8];
        let (n, f) = (2usize, 2usize);
        let mut nodes = vec![0.0; n * f];
        let mut adj = vec![0.0; n * n];
        let mut mask = vec![0.0; n];
        sg.fill_padded(&g, 0, AdjNorm::RowMean, n, f, Some(&alt), &mut nodes,
                       &mut adj, &mut mask);
        assert_eq!(nodes, vec![7.0; 4]);
    }

    #[test]
    #[should_panic(expected = "segment")]
    fn oversize_segment_panics() {
        let g = path4();
        let sg = SegmentedGraph::new(&g, &two_segments());
        let mut nodes = vec![0.0; 2];
        let mut adj = vec![0.0; 1];
        let mut mask = vec![0.0; 1];
        sg.fill_padded(&g, 0, AdjNorm::RowMean, 1, 2, None, &mut nodes,
                       &mut adj, &mut mask);
    }

    #[test]
    #[should_panic(expected = "segment")]
    fn oversize_prepared_fill_panics() {
        let g = path4();
        let sg = SegmentedGraph::new(&g, &two_segments());
        let prep = PreparedSegments::new(&g, &sg, AdjNorm::RowMean, 1, 2);
        let mut nodes = vec![0.0; 2];
        let mut adj = vec![0.0; 1];
        let mut mask = vec![0.0; 1];
        prep.fill(0, None, &mut nodes, &mut adj, &mut mask);
    }

    /// Prepared and cached fills are bit-identical to the direct
    /// `fill_padded` path — across both `AdjNorm` variants, edge-cut and
    /// vertex-cut segment sets, feature overrides, and padding slots
    /// (buffers are pre-filled with garbage to catch missed zeroing).
    #[test]
    fn prepared_and_cached_fills_match_fill_padded() {
        use crate::testing::prop::{forall, Gen};
        use crate::util::rng::Pcg64;
        forall("prepared fill == fill_padded", 40, Gen::usize(0..1_000_000),
               |&seed| {
            let mut rng = Pcg64::new(seed as u64, 0xf111);
            let n = 2 + rng.below(24);
            let fdim = 1 + rng.below(3);
            let mut b = GraphBuilder::new(n, fdim);
            for _ in 0..n + rng.below(3 * n) {
                b.add_edge(rng.below(n), rng.below(n));
            }
            for v in 0..n {
                let feat: Vec<f32> = (0..fdim).map(|_| rng.f32()).collect();
                b.set_feat(v, &feat);
            }
            let g = b.build();
            // random segment set: shuffled chunks, sorted within a segment
            let mut order: Vec<u32> = (0..n as u32).collect();
            rng.shuffle(&mut order);
            let seg_size = 1 + rng.below(n);
            let segments: Vec<Vec<u32>> = order
                .chunks(seg_size)
                .map(|c| {
                    let mut s = c.to_vec();
                    s.sort_unstable();
                    s
                })
                .collect();
            // half the cases use vertex-cut explicit edge lists (the
            // intra-segment edges, so both code paths agree on content)
            let edges = rng.coin(0.5).then(|| {
                segments
                    .iter()
                    .map(|seg| {
                        let inset: std::collections::HashSet<u32> =
                            seg.iter().copied().collect();
                        let mut es = Vec::new();
                        for &u in seg {
                            for &w in g.neighbors(u as usize) {
                                if u < w && inset.contains(&w) {
                                    es.push((u, w));
                                }
                            }
                        }
                        es
                    })
                    .collect()
            });
            let set = SegmentSet { segments, edges };
            let sg = SegmentedGraph::new(&g, &set);
            let maxseg =
                set.segments.iter().map(|s| s.len()).max().unwrap();
            let mn = maxseg + rng.below(4); // padding slots included
            let alt: Vec<f32> =
                (0..n * fdim).map(|_| rng.f32()).collect();
            for norm in [AdjNorm::RowMean, AdjNorm::SymSelfLoop] {
                let prep = PreparedSegments::new(&g, &sg, norm, mn, fdim);
                let cache =
                    FillCache::new(4, mn * fdim, mn * mn, mn).unwrap();
                for si in 0..sg.num_segments() {
                    for ovr in [None, Some(alt.as_slice())] {
                        let mut direct = (
                            vec![9f32; mn * fdim],
                            vec![9f32; mn * mn],
                            vec![9f32; mn],
                        );
                        sg.fill_padded(&g, si, norm, mn, fdim, ovr,
                                       &mut direct.0, &mut direct.1,
                                       &mut direct.2);
                        let mut p = (
                            vec![8f32; mn * fdim],
                            vec![8f32; mn * mn],
                            vec![8f32; mn],
                        );
                        prep.fill(si, ovr, &mut p.0, &mut p.1, &mut p.2);
                        if p != direct {
                            return false;
                        }
                        if ovr.is_none() {
                            // cached round trip: miss-fill-put, then hit
                            let key = si as u64;
                            if !cache.get(0, key, &mut p.0, &mut p.1, &mut p.2)
                            {
                                cache.put(0, key, &p.0, &p.1, &p.2);
                            }
                            let mut c = (
                                vec![7f32; mn * fdim],
                                vec![7f32; mn * mn],
                                vec![7f32; mn],
                            );
                            if !cache.get(0, key, &mut c.0, &mut c.1, &mut c.2)
                            {
                                return false;
                            }
                            if c != direct {
                                return false;
                            }
                        }
                    }
                }
            }
            true
        });
    }

    /// Short final chunks pad trailing batch slots by repeating the last
    /// entry (`train::core::padded_index`); the prepared path must match
    /// the direct path on those repeated fills too.
    #[test]
    fn prepared_fill_matches_on_short_chunk_padding() {
        let g = path4();
        let sg = SegmentedGraph::new(&g, &two_segments());
        let prep = PreparedSegments::new(&g, &sg, AdjNorm::SymSelfLoop, 3, 2);
        let chunk = [1usize]; // 1-entry chunk padded to a 3-slot batch
        let (n, f, b) = (3usize, 2usize, 3usize);
        let mut direct =
            (vec![0f32; b * n * f], vec![0f32; b * n * n], vec![0f32; b * n]);
        let mut prepd =
            (vec![1f32; b * n * f], vec![1f32; b * n * n], vec![1f32; b * n]);
        for slot in 0..b {
            let s = chunk[crate::train::core::padded_index(slot, chunk.len())];
            sg.fill_padded(
                &g, s, AdjNorm::SymSelfLoop, n, f, None,
                &mut direct.0[slot * n * f..(slot + 1) * n * f],
                &mut direct.1[slot * n * n..(slot + 1) * n * n],
                &mut direct.2[slot * n..(slot + 1) * n],
            );
            prep.fill(
                s, None,
                &mut prepd.0[slot * n * f..(slot + 1) * n * f],
                &mut prepd.1[slot * n * n..(slot + 1) * n * n],
                &mut prepd.2[slot * n..(slot + 1) * n],
            );
        }
        assert_eq!(direct, prepd);
    }
}
