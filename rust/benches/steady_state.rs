//! Steady-state step allocation audit: drives the exact post-warmup
//! inner-loop machinery the trainer uses — indexed RNG streams,
//! `sed::draw_into`, the warm shared fill cache, pooled XLA literals and
//! the batched [`CommitBatch`] write-back — under a counting global
//! allocator, against a "legacy" arm shaped like the pre-reuse code
//! (format!-keyed streams, allocating SED draws, per-step staging vecs,
//! per-row table puts). Needs no AOT artifacts. Emits
//! BENCH_steady_alloc.json; CI asserts `alloc_per_step_after == 0`.
//!
//!     cargo bench --bench steady_state
//!
//! [`CommitBatch`]: gst::train::core::CommitBatch

#[path = "harness.rs"]
mod harness;

use std::alloc::{GlobalAlloc, Layout, System};
use std::hint::black_box;
use std::sync::atomic::{AtomicU64, Ordering};

use gst::datasets::{MalnetDataset, MalnetSplit};
use gst::partition::Algorithm;
use gst::sed;
use gst::segment::{AdjNorm, FillHandle, PreparedSegments, SegmentedGraph};
use gst::table::EmbeddingTable;
use gst::train::core::CommitBatch;
use gst::util::rng::Pcg64;

/// System-allocator wrapper counting every heap acquisition. Frees are
/// not counted: the invariant under test is "the steady-state step
/// acquires no heap memory", and realloc/alloc_zeroed are acquisitions.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

const MAX_NODES: usize = 128;
const FEAT: usize = 16;
const TD: usize = 4;
const KEEP_P: f32 = 0.5;
/// steps run before counting (fills caches, pools, buffer capacities)
const WARM: usize = 64;
/// steps in each counted window
const COUNT: usize = 256;

fn block_key(g: usize, s: usize) -> u64 {
    ((g as u64) << 24) | s as u64
}

fn main() {
    let data = MalnetDataset::generate(MalnetSplit::Large, 12, 0);
    let mut prng = Pcg64::new(0, 0x5d).stream("partition");
    let segs: Vec<SegmentedGraph> = data
        .graphs
        .iter()
        .map(|g| {
            let set = Algorithm::MetisLike.partition(g, MAX_NODES, &mut prng);
            SegmentedGraph::new(g, &set)
        })
        .collect();
    let prepared: Vec<PreparedSegments> = data
        .graphs
        .iter()
        .zip(&segs)
        .map(|(g, sg)| {
            PreparedSegments::new(g, sg, AdjNorm::SymSelfLoop, MAX_NODES, FEAT)
        })
        .collect();
    let rows: Vec<usize> = segs.iter().map(|s| s.num_segments()).collect();
    let batch = rows.len();
    println!(
        "\nsteady-state step ({} graphs, {} segments, N={}, F={}, td={}):",
        batch,
        rows.iter().sum::<usize>(),
        MAX_NODES,
        FEAT,
        TD
    );

    let mut nodes = vec![0f32; MAX_NODES * FEAT];
    let mut adj = vec![0f32; MAX_NODES * MAX_NODES];
    let mut mask = vec![0f32; MAX_NODES];

    // a budget large enough for every block: steady state is all hits
    let mut fill = FillHandle::new(
        256,
        true,
        MAX_NODES * FEAT,
        MAX_NODES * MAX_NODES,
        MAX_NODES,
    );
    fill.bind_generation(1);
    assert!(fill.is_enabled());
    let mut table = EmbeddingTable::new(&rows, TD);
    for (g, &j) in rows.iter().enumerate() {
        for s in 0..j {
            prepared[g].fill(s, None, &mut nodes, &mut adj, &mut mask);
            fill.put(block_key(g, s), &nodes, &adj, &mask);
            table.put(g, s, &[0.1; TD], 0);
        }
    }

    // step-owned reusable state (the trainer's core-owned equivalents)
    let root = Pcg64::new(7, 0x57ed);
    let mut commit = CommitBatch::with_capacity(2 * batch, TD);
    let mut eta: Vec<f32> = Vec::new();
    let mut h_s = vec![0f32; batch * TD];
    let mut stale_sum = [0f32; TD];

    // One optimization step over the whole batch. `legacy = true` runs
    // the pre-reuse shape of the same work: format!-keyed RNG streams,
    // allocating SED draws, a fresh staging vec per write-back, and
    // per-row table puts instead of one batched flush.
    let mut step = |i: u64, legacy: bool| -> f32 {
        let mut acc = 0f32;
        let mut rng = if legacy {
            root.stream(&format!("step{i}"))
        } else {
            root.stream_indexed("step", i)
        };
        commit.begin();
        for (g, &j) in rows.iter().enumerate() {
            let s = rng.below(j);
            let eta_fresh = if legacy {
                let w = sed::draw(j, &[s], KEEP_P, &mut rng);
                eta.clear();
                eta.extend_from_slice(&w.eta_stale);
                w.eta_fresh
            } else {
                sed::draw_into(j, &[s], KEEP_P, &mut rng, &mut eta)
            };
            // stale reads from the table snapshot, SED-weighted
            stale_sum.fill(0.0);
            for seg in 0..j {
                if seg == s {
                    continue;
                }
                if let Some(h) = table.get(g, seg) {
                    for (d, x) in h.iter().enumerate() {
                        stale_sum[d] += eta[seg] * x;
                    }
                }
            }
            // sampled segment's block via the warm shared fill cache
            let hit = fill.get(block_key(g, s), &mut nodes, &mut adj, &mut mask);
            assert!(hit, "steady state must be all cache hits");
            // host->device marshalling: the pooled literal cycle
            let lit = xla::Literal::vec1(&mask);
            let lit2 = lit.reshape(&[1, MAX_NODES as i64]).unwrap();
            acc += lit2.dims()[1] as f32
                + eta_fresh
                + stale_sum[0]
                + nodes[0]
                + adj[0];
            // the sampled segment's fresh-embedding write-back
            let hv = (i as f32).mul_add(1e-3, g as f32);
            if legacy {
                let row = vec![hv; TD];
                table.put(g, s, &row, i as u32 + 1);
            } else {
                h_s[g * TD..(g + 1) * TD].fill(hv);
                commit.push(table.slot_index(g, s));
            }
        }
        if !legacy {
            commit.flush(&mut table, i as u32 + 1, |id| {
                let k = id as usize;
                &h_s[k * TD..(k + 1) * TD]
            });
        }
        acc
    };

    let mut i = 0u64;
    let mut acc = 0f32;

    // -- allocation counting (plain loops: Bench itself allocates) --
    for _ in 0..WARM {
        i += 1;
        acc += step(i, false);
    }
    let a0 = allocs();
    for _ in 0..COUNT {
        i += 1;
        acc += step(i, false);
    }
    let after_delta = allocs() - a0;

    for _ in 0..WARM {
        i += 1;
        acc += step(i, true);
    }
    let b0 = allocs();
    for _ in 0..COUNT {
        i += 1;
        acc += step(i, true);
    }
    let before_delta = allocs() - b0;

    let per = |d: u64| d as f64 / COUNT as f64;
    println!(
        "heap acquisitions/step: legacy {:.1}, steady {:.1} \
         (over {} counted steps)",
        per(before_delta),
        per(after_delta),
        COUNT
    );

    // -- wall-clock (separate pass: the harness's sample vec allocates) --
    let bench =
        harness::Bench::new("steady step (reused/batched)").warmup(8).iters(40);
    let after_ms = bench.run(|| {
        i += 1;
        step(i, false)
    });
    let bench =
        harness::Bench::new("legacy step (alloc per step)").warmup(8).iters(40);
    let before_ms = bench.run(|| {
        i += 1;
        step(i, true)
    });
    black_box(acc);

    let series = vec![
        ("alloc_per_step_after".to_string(), per(after_delta)),
        ("alloc_per_step_before".to_string(), per(before_delta)),
        ("step_us_after".to_string(), after_ms * 1e3),
        ("step_us_before".to_string(), before_ms * 1e3),
    ];
    harness::emit_json_unit("steady_alloc", "per_step", &series, false);

    assert_eq!(
        after_delta, 0,
        "steady-state step performed {after_delta} heap acquisitions \
         over {COUNT} steps — the reuse contract is broken"
    );
}
