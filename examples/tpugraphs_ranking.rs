//! TpuGraphs config ranking — predict which compiler configuration runs
//! fastest on each HLO-like graph, scored by ordered pair accuracy.
//!
//!     cargo run --release --example tpugraphs_ranking

use gst::datasets::TpuDataset;
use gst::runtime::Engine;
use gst::train::{Method, TpuTrainer, TrainConfig};

fn main() -> anyhow::Result<()> {
    let eng = Engine::open("artifacts/tpu_sage_n128")?;
    let data = TpuDataset::generate(12, 8, 21);
    let pairs: usize = data.graphs.iter().map(|g| g.configs.len()).sum();
    println!(
        "TpuGraphs analogue: {} graphs x ~8 layout configs = {} samples",
        data.graphs.len(),
        pairs
    );
    println!("\n{:<22} {:>10} {:>10} {:>10}", "method", "train OPA",
             "test OPA", "ms/step");
    for method in [Method::Gst, Method::GstOne, Method::GstE, Method::GstED] {
        let cfg = TrainConfig {
            method,
            epochs: 5,
            finetune_epochs: 0, // F' is a sum here — nothing to finetune
            eval_every: 5,
            seed: 21,
            ..TrainConfig::default()
        };
        let mut tr = TpuTrainer::new(&eng, &data, cfg)?;
        let res = tr.train()?;
        println!(
            "{:<22} {:>10.3} {:>10.3} {:>10.1}",
            method.name(), res.train_metric, res.test_metric, res.step_ms
        );
    }
    // the end goal: pick the best config per graph with the trained model
    println!("\n(the OPA metric scores exactly the ranking the compiler\n\
              autotuner needs: higher OPA -> better config selection)");
    Ok(())
}
