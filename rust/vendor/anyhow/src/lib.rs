//! Minimal offline stand-in for the `anyhow` crate (crates.io is
//! unavailable in the build image — DESIGN.md §3). Implements exactly the
//! surface the `gst` crate uses: a string-backed [`Error`], the [`anyhow!`]
//! and [`bail!`] macros, the [`Context`] extension trait, and the
//! [`Result`] alias. Drop-in replaceable by the real crate.

use std::fmt;

/// String-backed error. Like `anyhow::Error`, this type deliberately does
/// NOT implement `std::error::Error`, which is what makes the blanket
/// `From<E: std::error::Error>` conversion below coherent.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(|| ..)` on results and options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Early-return with an [`anyhow!`] error when `cond` is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "boom")
    }

    #[test]
    fn display_and_debug_show_message() {
        let e = anyhow!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
        assert_eq!(format!("{e:?}"), "x = 7");
    }

    #[test]
    fn inline_captures_work() {
        let path = "p.json";
        let e = anyhow!("read {path}");
        assert_eq!(e.to_string(), "read p.json");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "boom");
    }

    #[test]
    fn with_context_prefixes() {
        let r: std::result::Result<(), _> = Err(io_err());
        let e = r.with_context(|| "reading x".to_string()).unwrap_err();
        assert_eq!(e.to_string(), "reading x: boom");
    }

    #[test]
    fn bail_returns_early() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("OOM: over budget ({} > {})", 3, 2);
            }
            Ok(1)
        }
        assert!(f(true).unwrap_err().to_string().contains("OOM"));
        assert_eq!(f(false).unwrap(), 1);
    }

    #[test]
    fn ensure_checks_condition() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(30).unwrap_err().to_string().contains("too big"));
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_ss<T: Send + Sync>() {}
        assert_ss::<Error>();
    }
}
