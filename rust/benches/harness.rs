//! Minimal bench harness (criterion is unavailable offline — DESIGN.md §3):
//! warmup, timed iterations, trimmed-mean / p50 / stddev reporting.
//! Included by each bench target via `#[path = "harness.rs"] mod harness;`.

#![allow(dead_code)]

use std::time::Instant;

pub struct Bench {
    name: String,
    warmup: usize,
    iters: usize,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        Bench { name: name.to_string(), warmup: 2, iters: 10 }
    }

    pub fn warmup(mut self, n: usize) -> Bench {
        self.warmup = n;
        self
    }

    pub fn iters(mut self, n: usize) -> Bench {
        self.iters = n;
        self
    }

    /// Run `f` (warmup + timed) and print a stats line. Returns mean ms.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> f64 {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let mean = trimmed_mean(&samples, 0.1);
        let sd = stddev(&samples);
        let p50 = percentile(&samples, 50.0);
        println!(
            "{:<44} {:>10.3} ms  ±{:>8.3}  p50 {:>10.3}  n={}",
            self.name, mean, sd, p50, self.iters
        );
        mean
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let (lo, hi) = (rank.floor() as usize, rank.ceil() as usize);
    if lo == hi { v[lo] } else { v[lo] + (rank - lo as f64) * (v[hi] - v[lo]) }
}

pub fn trimmed_mean(xs: &[f64], frac: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let k = ((v.len() as f64) * frac).floor() as usize;
    mean(&v[k..v.len() - k.min(v.len() - 1)])
}

pub fn artifacts(variant: &str) -> Option<String> {
    let d = format!("{}/artifacts/{variant}", env!("CARGO_MANIFEST_DIR"));
    std::path::Path::new(&d).is_dir().then_some(d)
}

/// Write a bench's (label, mean ms) series as a perf-trajectory JSON
/// record (`BENCH_<name>.json` at the repository root, or
/// `$BENCH_OUT/BENCH_<name>.json`), the format CI accumulates run over
/// run. The repo-root default means a plain `cargo bench` lands the
/// artifact where CI uploads it from, regardless of the invocation cwd.
/// A run that had to skip (artifacts not built) still writes the file
/// with `skipped: true` so the trajectory has no silent holes.
pub fn emit_json(name: &str, entries: &[(String, f64)], skipped: bool) {
    use gst::util::json::Json;
    let payload = Json::obj(vec![
        ("bench", Json::str(name)),
        ("unit", Json::str("ms")),
        ("skipped", Json::Bool(skipped)),
        (
            "results",
            Json::arr(entries.iter().map(|(label, ms)| {
                Json::obj(vec![
                    ("label", Json::str(label)),
                    ("mean_ms", Json::num(*ms)),
                ])
            })),
        ),
    ]);
    let dir = std::env::var("BENCH_OUT")
        .unwrap_or_else(|_| repo_root().into());
    let path = format!("{dir}/BENCH_{name}.json");
    match std::fs::write(&path, payload.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("emit_json: {path}: {e}"),
    }
}

/// Default artifact directory: the repository root (one level above the
/// cargo workspace), fixed at compile time so it never depends on cwd.
fn repo_root() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/..")
}

/// Like [`emit_json`] but for benches whose natural unit is not
/// milliseconds (e.g. ns per fill): the record carries an explicit
/// `unit` and a unit-neutral `mean` value key. `emit_json`'s `mean_ms`
/// layout stays untouched for the existing trajectory consumers.
pub fn emit_json_unit(
    name: &str,
    unit: &str,
    entries: &[(String, f64)],
    skipped: bool,
) {
    use gst::util::json::Json;
    let payload = Json::obj(vec![
        ("bench", Json::str(name)),
        ("unit", Json::str(unit)),
        ("skipped", Json::Bool(skipped)),
        (
            "results",
            Json::arr(entries.iter().map(|(label, v)| {
                Json::obj(vec![
                    ("label", Json::str(label)),
                    ("mean", Json::num(*v)),
                ])
            })),
        ),
    ]);
    let dir = std::env::var("BENCH_OUT")
        .unwrap_or_else(|_| repo_root().into());
    let path = format!("{dir}/BENCH_{name}.json");
    match std::fs::write(&path, payload.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("emit_json_unit: {path}: {e}"),
    }
}
