//! Table 3 bench: average training time per iteration on MalNet-Large,
//! per method. This is the wall-clock claim behind "GST+EFD is 3x faster
//! than GST": GST re-encodes every stale segment, the table methods don't.
//! Emits BENCH_step_ms.json for the CI perf trajectory.
//!
//!     cargo bench --bench table3_runtime

#[path = "harness.rs"]
mod harness;

use gst::datasets::{MalnetDataset, MalnetSplit};
use gst::runtime::Engine;
use gst::train::{MalnetTrainer, Method, TrainConfig};

fn main() {
    let Some(dir) = harness::artifacts("malnet_sage_n128") else {
        println!("table3_runtime: artifacts not built, skipping");
        harness::emit_json("step_ms", &[], true);
        return;
    };
    let eng = Engine::open(&dir).unwrap();
    let data = MalnetDataset::generate(MalnetSplit::Large, 18, 0);
    let mut series = Vec::new();
    println!("\nTable 3 (per-iteration train time, MalNet-Large, SAGE):");
    for method in
        [Method::Gst, Method::GstOne, Method::GstE, Method::GstEFD]
    {
        let cfg = TrainConfig {
            method,
            epochs: 8,
            finetune_epochs: 0,
            eval_every: 99,
            seed: 0,
            ..TrainConfig::default()
        };
        let mut tr = MalnetTrainer::new(&eng, &data, cfg).unwrap();
        let res = tr.train().unwrap();
        println!(
            "{:<44} {:>10.1} ms/step ({} grad_steps, {} embed_fwd)",
            method.name(),
            res.step_ms,
            res.call_counts.get("grad_step").unwrap_or(&0),
            res.call_counts.get("embed_fwd").unwrap_or(&0),
        );
        series.push((method.name().to_string(), res.step_ms));
    }
    harness::emit_json("step_ms", &series, false);
}
