//! Quickcheck-style property testing with generators and greedy shrinking.
//!
//! ```no_run
//! use gst::testing::prop::{forall, Gen};
//! forall("sorted idempotent", 100, Gen::vec_usize(0..64, 0..100), |xs| {
//!     let mut a = xs.clone();
//!     a.sort_unstable();
//!     let mut b = a.clone();
//!     b.sort_unstable();
//!     a == b
//! });
//! ```
//!
//! On failure the input is shrunk (halving-style) and the minimal
//! counterexample is included in the panic message.

use crate::util::rng::Pcg64;
use std::fmt::Debug;
use std::ops::Range;

/// A reusable generator of random values plus a shrinking strategy.
pub struct Gen<T> {
    pub sample: Box<dyn Fn(&mut Pcg64) -> T>,
    pub shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl Gen<usize> {
    pub fn usize(range: Range<usize>) -> Gen<usize> {
        let (lo, hi) = (range.start, range.end);
        Gen {
            sample: Box::new(move |rng| lo + rng.below(hi - lo)),
            shrink: Box::new(move |&x| {
                let mut out = vec![];
                if x > lo {
                    out.push(lo);
                    out.push(lo + (x - lo) / 2);
                    out.push(x - 1); // lets greedy descent find boundaries
                }
                out
            }),
        }
    }
}

impl Gen<f64> {
    pub fn f64_unit() -> Gen<f64> {
        Gen {
            sample: Box::new(|rng| rng.f64()),
            shrink: Box::new(|&x| {
                if x > 1e-9 {
                    vec![0.0, x / 2.0]
                } else {
                    vec![]
                }
            }),
        }
    }
}

impl Gen<Vec<usize>> {
    pub fn vec_usize(len: Range<usize>, val: Range<usize>) -> Gen<Vec<usize>> {
        let (llo, lhi) = (len.start, len.end);
        let (vlo, vhi) = (val.start, val.end);
        Gen {
            sample: Box::new(move |rng| {
                let n = llo + rng.below((lhi - llo).max(1));
                (0..n).map(|_| vlo + rng.below(vhi - vlo)).collect()
            }),
            shrink: Box::new(move |xs| {
                let mut out = vec![];
                if xs.len() > llo {
                    out.push(xs[..(xs.len() / 2).max(llo)].to_vec());
                    // drop each single element
                    for i in 0..xs.len() {
                        let mut dropped = xs.clone();
                        dropped.remove(i);
                        out.push(dropped);
                    }
                }
                // element-wise halving toward vlo, plus single decrements
                // so greedy descent can land exactly on failure boundaries
                for i in 0..xs.len() {
                    if xs[i] > vlo {
                        let mut smaller = xs.clone();
                        smaller[i] = vlo + (xs[i] - vlo) / 2;
                        out.push(smaller);
                        let mut dec = xs.clone();
                        dec[i] -= 1;
                        out.push(dec);
                    }
                }
                out
            }),
        }
    }
}

/// Pair two generators.
pub fn zip<A: Clone + 'static, B: Clone + 'static>(
    a: Gen<A>,
    b: Gen<B>,
) -> Gen<(A, B)> {
    let (sa, sha) = (a.sample, a.shrink);
    let (sb, shb) = (b.sample, b.shrink);
    Gen {
        sample: Box::new(move |rng| (sa(rng), sb(rng))),
        shrink: Box::new(move |(x, y)| {
            let mut out: Vec<(A, B)> =
                sha(x).into_iter().map(|x2| (x2, y.clone())).collect();
            out.extend(shb(y).into_iter().map(|y2| (x.clone(), y2)));
            out
        }),
    }
}

/// Check `prop` on `cases` random inputs; on failure, shrink and panic with
/// the minimal counterexample found.
pub fn forall<T: Clone + Debug>(
    name: &str,
    cases: usize,
    gen: Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Pcg64::new(0x675f, 0x1e57);
    for case in 0..cases {
        let input = (gen.sample)(&mut rng);
        if !prop(&input) {
            let minimal = shrink_loop(&gen, &prop, input);
            panic!(
                "property `{name}` failed (case {case});\n\
                 minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<T: Clone + Debug>(
    gen: &Gen<T>,
    prop: &impl Fn(&T) -> bool,
    mut failing: T,
) -> T {
    // Greedy descent: keep taking the first failing shrink, up to a cap.
    for _ in 0..1000 {
        let candidates = (gen.shrink)(&failing);
        match candidates.into_iter().find(|c| !prop(c)) {
            Some(smaller) => failing = smaller,
            None => break,
        }
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_succeeds() {
        forall("reverse twice", 50, Gen::vec_usize(0..20, 0..100), |xs| {
            let mut a = xs.clone();
            a.reverse();
            a.reverse();
            a == *xs
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            forall("no large elems", 100, Gen::vec_usize(0..20, 0..100), |xs| {
                xs.iter().all(|&x| x < 50)
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // minimal counterexample should be a single element equal to 50
        assert!(msg.contains("[50]"), "got: {msg}");
    }

    #[test]
    fn usize_gen_respects_range() {
        let g = Gen::usize(5..10);
        let mut rng = Pcg64::new(1, 1);
        for _ in 0..100 {
            let x = (g.sample)(&mut rng);
            assert!((5..10).contains(&x));
        }
    }

    #[test]
    fn zip_shrinks_both_sides() {
        let g = zip(Gen::usize(0..100), Gen::usize(0..100));
        let shrinks = (g.shrink)(&(40, 60));
        assert!(shrinks.iter().any(|&(a, b)| a < 40 && b == 60));
        assert!(shrinks.iter().any(|&(a, b)| a == 40 && b < 60));
    }
}
