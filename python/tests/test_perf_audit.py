"""Perf regressions as tests: L1 schedules must fit VMEM, and the lowered
grad_step must not re-trace the forward (dot-count audit, §Perf L2)."""

import os

import pytest

from compile import perf_report
from compile.config import default_variants

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.parametrize("cfg", default_variants(), ids=lambda c: c.name)
def test_l1_schedules_fit_vmem(cfg):
    for (name, vmem, _util) in perf_report.l1_report(cfg):
        assert vmem < perf_report.VMEM_BUDGET, name


def test_mxu_utilization_reported_in_range():
    cfg = default_variants()[0]
    for (_name, _vmem, util) in perf_report.l1_report(cfg):
        if util == util:  # skip NaN (attention has no MXU estimate)
            assert 0.0 < util <= 1.0


@pytest.mark.parametrize("cfg", default_variants(), ids=lambda c: c.name)
def test_l2_no_forward_recomputation(cfg):
    """fwd+bwd needs at most ~3x the forward's matmuls (each fwd dot
    contributes <= 2 bwd dots). Ratios above ~3 mean a re-traced forward
    or un-DCE'd dead cotangents (both regressions we've hit)."""
    vdir = os.path.join(ART, cfg.name)
    if not os.path.isfile(os.path.join(vdir, "grad_step.hlo.txt")):
        pytest.skip("artifacts not built")
    audit = perf_report.l2_audit(ART, cfg.name)
    ratio = audit["grad_step"]["dot"] / max(1, audit["embed_fwd"]["dot"])
    # grad_step additionally contains the head (absent from embed_fwd) and
    # one dead pre-layer dx, so the practical optimum sits at ~3.2-3.7;
    # the pre-fix regression (dead adjacency cotangents) measured 3.75-4.0.
    assert ratio <= 3.7, f"{cfg.name}: dot ratio {ratio:.2f}"


def test_hlo_has_no_custom_calls():
    """interpret=True must lower to pure HLO: a Mosaic custom-call would
    break the CPU PJRT path entirely."""
    if not os.path.isdir(ART):
        pytest.skip("artifacts not built")
    for v in sorted(os.listdir(ART)):
        vdir = os.path.join(ART, v)
        if not os.path.isdir(vdir):
            continue
        for f in os.listdir(vdir):
            if f.endswith(".hlo.txt"):
                counts = perf_report.hlo_op_counts(os.path.join(vdir, f))
                assert counts["custom-call"] == 0, f"{v}/{f}"
